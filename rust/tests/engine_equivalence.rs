//! Cross-engine equivalence and sequential-consistency properties,
//! exercised through the unified `engine::Engine` builder.
//!
//! The GraphLab guarantee (paper Def. 3.1): every parallel execution has
//! an equivalent sequential execution. For deterministic-schedule programs
//! this means the distributed engines must agree exactly with a sequential
//! shared-memory run; for adaptive programs they must agree on the fixed
//! point. The unified API makes the parameterization literal: one harness
//! function, every `EngineKind`.

use graphlab::apps::{self, als, pagerank};
use graphlab::distributed::TransportKind;
use graphlab::engine::{Engine, EngineKind, ENGINE_KINDS};
use graphlab::partition::{Coloring, Partition};
use graphlab::scheduler::{Policy, SchedSpec};

mod common;
use common::assert_ranks_close;

/// The parameterized cross-engine harness: run PageRank to its fixed
/// point on `kind` (via the shared `common::pagerank_fixed_point`
/// helper) and return the final ranks after validating the stats.
fn pagerank_ranks(kind: EngineKind, n: usize, edges: &[(u32, u32)], eps: f32) -> Vec<f32> {
    let (ranks, stats) =
        common::pagerank_fixed_point(kind, TransportKind::InProc, 3, n, edges, eps);
    assert!(stats.updates >= n as u64, "{kind}: only {} updates", stats.updates);
    // The balance vector must be real per-machine accounting: one slot
    // per machine, and every machine did work (the initial task set
    // touches every vertex, and every machine owns some).
    let expected_machines = if kind.is_distributed() { 3 } else { 1 };
    assert_eq!(
        stats.updates_per_machine.len(),
        expected_machines,
        "{kind}: wrong balance-vector length"
    );
    assert!(
        stats.updates_per_machine.iter().all(|&u| u > 0),
        "{kind}: a machine reported zero updates: {:?}",
        stats.updates_per_machine
    );
    // Guards future drift: the total must stay derived from (or at least
    // consistent with) the per-machine accounting.
    assert_eq!(
        stats.updates_per_machine.iter().sum::<u64>(),
        stats.updates,
        "{kind}: per-machine counts must sum to the total"
    );
    ranks
}

#[test]
fn engine_kind_from_str_rejects_unknown_names() {
    assert_eq!("shared".parse::<EngineKind>().unwrap(), EngineKind::Shared);
    assert_eq!(
        "chromatic".parse::<EngineKind>().unwrap(),
        EngineKind::Chromatic
    );
    assert_eq!("locking".parse::<EngineKind>().unwrap(), EngineKind::Locking);
    for bad in ["", "mpi", "Shared", "LOCKING", "chromatic "] {
        assert!(
            bad.parse::<EngineKind>().is_err(),
            "'{bad}' should not parse"
        );
    }
}

#[test]
fn all_engines_reach_same_pagerank_fixed_point() {
    // One workload, every engine, one assertion loop: the unified API's
    // core promise (the update function never changes, only EngineKind).
    let n = 800;
    let edges = graphlab::datagen::web_graph(n, 6, 17);
    let oracle = pagerank_ranks(EngineKind::Shared, n, &edges, 1e-7);
    for kind in ENGINE_KINDS {
        if kind == EngineKind::Shared {
            continue;
        }
        let got = pagerank_ranks(kind, n, &edges, 1e-7);
        assert_ranks_close(kind.name(), &oracle, &got, 1e-5);
    }
}

#[test]
fn chromatic_machine_count_does_not_change_results() {
    // The chromatic schedule is deterministic regardless of machine count
    // (paper Sec. 4.2.1 "repeated invocations ... will always produce
    // identical update sequences, regardless of the number of machines").
    let data = graphlab::datagen::netflix(120, 80, 12, 4, 0.1, 3);
    let run = |machines: usize| {
        let g = als::build(&data, 5, 1);
        let n = g.num_vertices();
        let prog = als::Als { d: 5, lambda: 0.1, use_pjrt: false };
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(machines)
            .max_sweeps(6)
            .with_coloring(Coloring::bipartite(&g).unwrap())
            .with_partition(Partition::random(n, machines, 9))
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
        let g = exec.graph;
        g.vertex_ids().flat_map(|v| g.vertex_data(v).factor.clone()).collect::<Vec<f32>>()
    };
    let f1 = run(1);
    let f3 = run(3);
    let f5 = run(5);
    // Color-internal order differs but updates are independent within a
    // color, so results agree to float reduction order (exact here since
    // per-vertex accumulation order is scope order in every engine).
    for ((a, b), c) in f1.iter().zip(&f3).zip(&f5) {
        assert!((a - b).abs() < 1e-5 && (a - c).abs() < 1e-5, "{a} {b} {c}");
    }
}

#[test]
fn shared_engine_scheduler_variants_agree_on_pagerank_fixed_point() {
    // The work-stealing queue organizations (per policy) and the
    // single-global-queue baseline must all converge to the same PageRank
    // fixed point the sequential oracle reaches — execution order may
    // differ, the answer may not.
    let n = 500;
    let edges = graphlab::datagen::web_graph(n, 6, 23);
    let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-7, n, use_pjrt: false };
    let run = |spec: SchedSpec, workers: usize| {
        let g = pagerank::build(n, &edges, 0.15);
        let exec = Engine::new(EngineKind::Shared)
            .workers(workers)
            .scheduler(spec)
            .max_updates(3_000_000)
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
        assert!(
            exec.stats.updates >= n as u64,
            "{}: {}",
            spec.name(),
            exec.stats.updates
        );
        let g = exec.graph;
        g.vertex_ids().map(|v| g.vertex_data(v).rank).collect::<Vec<f32>>()
    };
    // Sequential oracle: one worker, plain FIFO.
    let oracle = run(SchedSpec::ws(Policy::Fifo, 1), 1);
    for policy in graphlab::scheduler::POLICIES {
        for spec in [SchedSpec::ws(policy, 11), SchedSpec::global(policy, 11)] {
            let got = run(spec, 4);
            assert_ranks_close(&spec.name(), &oracle, &got, 1e-5);
        }
    }
}

#[test]
fn single_worker_work_stealing_is_deterministic_and_matches_global() {
    // The determinism contract: with workers = 1 the work-stealing path
    // degenerates to the plain single-queue scheduler — no stealing, no
    // randomness — so repeated runs are bit-identical, and for FIFO the
    // pop order (hence the float-op order) matches the global baseline
    // exactly.
    let n = 300;
    let edges = graphlab::datagen::web_graph(n, 5, 41);
    let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-7, n, use_pjrt: false };
    let run = |spec: SchedSpec| {
        let g = pagerank::build(n, &edges, 0.15);
        let exec = Engine::new(EngineKind::Shared)
            .workers(1)
            .scheduler(spec)
            .max_updates(2_000_000)
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
        let g = exec.graph;
        g.vertex_ids().map(|v| g.vertex_data(v).rank.to_bits()).collect::<Vec<u32>>()
    };
    for policy in graphlab::scheduler::POLICIES {
        let a = run(SchedSpec::ws(policy, 5));
        let b = run(SchedSpec::ws(policy, 5));
        assert_eq!(a, b, "workers=1 nondeterministic under {}", policy.name());
    }
    // FIFO: work-stealing with one queue == the old global queue, bitwise.
    assert_eq!(
        run(SchedSpec::ws(Policy::Fifo, 5)),
        run(SchedSpec::global(Policy::Fifo, 5)),
        "single-worker ws-fifo diverged from the global-queue oracle"
    );
}

#[test]
fn locking_executor_pool_matches_oracle_across_thread_counts() {
    // The pump/pool split (ISSUE 10): granted batches evaluated by 1, 2,
    // or 4 executor threads per machine must all land on the sequential
    // shared-memory fixed point — the paper's Def. 3.1 guarantee is
    // independent of per-node core count.
    let n = 600;
    let edges = graphlab::datagen::web_graph(n, 6, 29);
    let oracle = pagerank_ranks(EngineKind::Shared, n, &edges, 1e-7);
    for workers in [1usize, 2, 4] {
        let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-7, n, use_pjrt: false };
        let g = pagerank::build(n, &edges, 0.15);
        let exec = Engine::new(EngineKind::Locking)
            .workers(workers)
            .machines(3)
            .maxpending(64)
            .max_updates(3_000_000)
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
        assert!(exec.stats.updates >= n as u64, "t{workers}: {}", exec.stats.updates);
        let g = exec.graph;
        let got: Vec<f32> = g.vertex_ids().map(|v| g.vertex_data(v).rank).collect();
        assert_ranks_close(&format!("locking t{workers}"), &oracle, &got, 1e-5);
    }
}

#[test]
fn locking_single_thread_is_bitwise_deterministic() {
    // threads == 1 keeps the pre-pool inline path: scopes point straight
    // into the local graph and evaluation order is the pump's, so
    // repeated single-machine runs are bit-identical — this is the
    // sequential oracle the pool path is validated against.
    let n = 300;
    let edges = graphlab::datagen::web_graph(n, 5, 37);
    let run = || {
        let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-7, n, use_pjrt: false };
        let g = pagerank::build(n, &edges, 0.15);
        let exec = Engine::new(EngineKind::Locking)
            .workers(1)
            .machines(1)
            .maxpending(64)
            .max_updates(2_000_000)
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
        let g = exec.graph;
        g.vertex_ids().map(|v| g.vertex_data(v).rank.to_bits()).collect::<Vec<u32>>()
    };
    assert_eq!(run(), run(), "threads=1 locking run must be bit-deterministic");
}

#[test]
fn locking_pool_write_scopes_never_overlap() {
    // Scope-isolation property: while one transaction's update runs, no
    // concurrently executing transaction may hold an overlapping *write*
    // scope — under edge consistency the center + adjacent edges, under
    // full consistency also the neighbor vertices. Each update claims
    // its write scope in atomic counters on entry and releases on exit;
    // any double-claim is a consistency violation. Run with a 4-thread
    // executor pool on every machine so claims really do race.
    use graphlab::engine::{Consistency, Ctx, Scope, VertexProgram};
    use graphlab::graph::GraphBuilder;
    use graphlab::wire::Wire;
    use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
    use std::sync::Arc;

    #[derive(Clone, Debug)]
    struct C(u64);
    impl Wire for C {
        fn encode(&self, out: &mut Vec<u8>) {
            self.0.encode(out);
        }
        fn decode(input: &mut &[u8]) -> graphlab::wire::Result<Self> {
            Ok(C(u64::decode(input)?))
        }
    }

    struct ClaimProbe {
        consistency: Consistency,
        vclaims: Arc<Vec<AtomicU32>>,
        eclaims: Arc<Vec<AtomicU32>>,
        violated: Arc<AtomicBool>,
        rounds: u64,
    }
    impl ClaimProbe {
        fn claim(&self, slot: &AtomicU32) {
            if slot.fetch_add(1, Ordering::SeqCst) != 0 {
                self.violated.store(true, Ordering::SeqCst);
            }
        }
    }
    impl VertexProgram<C, C> for ClaimProbe {
        fn consistency(&self) -> Consistency {
            self.consistency
        }
        fn update(&self, scope: &mut Scope<C, C>, ctx: &mut Ctx) {
            let c = scope.vertex() as usize;
            self.claim(&self.vclaims[c]);
            for i in 0..scope.degree() {
                self.claim(&self.eclaims[scope.edge_id(i) as usize]);
                if matches!(self.consistency, Consistency::Full) {
                    self.claim(&self.vclaims[scope.nbr_id(i) as usize]);
                }
            }
            // Widen the race window so a broken engine actually trips.
            std::thread::yield_now();
            scope.center_mut().0 += 1;
            if scope.center().0 < self.rounds {
                ctx.schedule(scope.vertex(), 1.0);
            }
            for i in (0..scope.degree()).rev() {
                if matches!(self.consistency, Consistency::Full) {
                    self.vclaims[scope.nbr_id(i) as usize].fetch_sub(1, Ordering::SeqCst);
                }
                self.eclaims[scope.edge_id(i) as usize].fetch_sub(1, Ordering::SeqCst);
            }
            self.vclaims[c].fetch_sub(1, Ordering::SeqCst);
        }
    }

    for consistency in [Consistency::Edge, Consistency::Full] {
        let n = 24u32;
        let mut b = GraphBuilder::new();
        b.add_vertices(n as usize, |_| C(0));
        for u in 0..n {
            for v in (u + 1)..n {
                if (u + v) % 3 == 0 {
                    b.add_edge(u, v, C(0));
                }
            }
        }
        let g = b.build();
        let m = g.num_edges();
        let vclaims: Arc<Vec<AtomicU32>> =
            Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
        let eclaims: Arc<Vec<AtomicU32>> =
            Arc::new((0..m).map(|_| AtomicU32::new(0)).collect());
        let violated = Arc::new(AtomicBool::new(false));
        let prog = ClaimProbe {
            consistency,
            vclaims: vclaims.clone(),
            eclaims: eclaims.clone(),
            violated: violated.clone(),
            rounds: 30,
        };
        let exec = Engine::new(EngineKind::Locking)
            .workers(4)
            .machines(3)
            .maxpending(16)
            .scheduler(SchedSpec::ws(Policy::Fifo, 1))
            .max_updates(300_000)
            .with_partition(Partition::striped(n as usize, 3))
            .run(g, &prog, apps::all_vertices(n as usize))
            .unwrap();
        assert!(exec.stats.updates >= n as u64);
        assert!(
            !violated.load(Ordering::SeqCst),
            "overlapping write scopes executed concurrently under {consistency:?}"
        );
        // Every claim was released — no transaction exited sideways.
        assert!(vclaims.iter().all(|c| c.load(Ordering::SeqCst) == 0));
        assert!(eclaims.iter().all(|c| c.load(Ordering::SeqCst) == 0));
    }
}

#[test]
fn locking_engine_respects_consistency_under_contention() {
    // Counter app where each update increments the center and all
    // neighbor-visible sums must stay exact (full consistency): any lost
    // update or torn read breaks the total.
    use graphlab::engine::{Consistency, Ctx, Scope, VertexProgram};
    use graphlab::graph::GraphBuilder;
    use graphlab::wire::Wire;

    #[derive(Clone, Debug, PartialEq)]
    struct C(u64);
    impl Wire for C {
        fn encode(&self, out: &mut Vec<u8>) {
            self.0.encode(out);
        }
        fn decode(input: &mut &[u8]) -> graphlab::wire::Result<Self> {
            Ok(C(u64::decode(input)?))
        }
    }
    struct IncAll {
        rounds: u64,
    }
    impl VertexProgram<C, C> for IncAll {
        fn consistency(&self) -> Consistency { Consistency::Full }
        fn update(&self, scope: &mut Scope<C, C>, ctx: &mut Ctx) {
            scope.center_mut().0 += 1;
            for i in 0..scope.degree() {
                scope.nbr_mut(i).0 += 1;
                scope.edge_mut(i).0 += 1;
            }
            if scope.center().0 < self.rounds {
                ctx.schedule(scope.vertex(), 1.0);
            }
        }
    }

    // Dense-ish graph, striped partition: maximal remote contention.
    // Exercised at 1, 2, and 4 executor threads per machine — the exact
    // count invariant is the sharpest lost-write detector we have for
    // the pool's snapshot/commit protocol.
    for workers in [1usize, 2, 4] {
        let n = 24u32;
        let mut b = GraphBuilder::new();
        b.add_vertices(n as usize, |_| C(0));
        for u in 0..n {
            for v in (u + 1)..n {
                if (u + v) % 3 == 0 {
                    b.add_edge(u, v, C(0));
                }
            }
        }
        let g = b.build();
        let m = g.num_edges() as u64;
        let prog = IncAll { rounds: 50 };
        let exec = Engine::new(EngineKind::Locking)
            .workers(workers)
            .machines(3)
            .maxpending(16)
            .scheduler(SchedSpec::ws(Policy::Fifo, 1))
            .max_updates(300_000)
            .with_partition(Partition::striped(n as usize, 3))
            .run(g, &prog, apps::all_vertices(n as usize))
            .unwrap();
        let (g, stats) = (exec.graph, exec.stats);
        // Every update increments center + degree neighbors + degree edges;
        // totals must match the update count exactly (no lost writes):
        // total_v = updates + total_e (each update adds deg to edges and deg
        // to neighbor vertices plus 1 to center).
        let total_v: u64 = g.vertex_ids().map(|v| g.vertex_data(v).0).sum();
        let total_e: u64 = (0..m as u32).map(|e| g.edge_data(e).0).sum();
        assert_eq!(total_v, stats.updates + total_e, "t{workers}: lost or torn writes");
    }
}
