//! Helpers shared by the integration-test binaries (`mod common;`).
//!
//! Each test binary compiles this module independently, so a helper used
//! by one binary is dead code in another — hence the file-level allow.
#![allow(dead_code)]

use graphlab::apps::{self, pagerank};
use graphlab::distributed::TransportKind;
use graphlab::engine::{Engine, EngineKind, ExecStats};
use graphlab::graph::{Graph, GraphBuilder, VertexId};
use graphlab::util::Rng;

/// Seeded random simple graph: `n` vertices, `m` distinct undirected
/// edges, no self-loops. Vertex data is the vertex id, edge data the
/// insertion index — enough structure to catch mixed-up indices.
pub fn random_graph(n: usize, m: usize, seed: u64) -> Graph<u32, u32> {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new();
    b.add_vertices(n, |i| i as u32);
    let mut seen = std::collections::HashSet::new();
    let mut added = 0;
    while added < m {
        let u = rng.gen_range(n) as VertexId;
        let v = rng.gen_range(n) as VertexId;
        if u != v && seen.insert((u.min(v), u.max(v))) {
            b.add_edge(u, v, added as u32);
            added += 1;
        }
    }
    b.build()
}

/// Run PageRank to its fixed point on `kind` over `transport`, returning
/// the final ranks plus the run's stats (for bytes/balance assertions).
pub fn pagerank_fixed_point(
    kind: EngineKind,
    transport: TransportKind,
    machines: usize,
    n: usize,
    edges: &[(u32, u32)],
    eps: f32,
) -> (Vec<f32>, ExecStats) {
    let prog = pagerank::PageRank { alpha: 0.15, eps, n, use_pjrt: false };
    let g = pagerank::build(n, edges, 0.15);
    let exec = Engine::new(kind)
        .workers(4)
        .machines(machines)
        .transport(transport)
        .maxpending(128)
        .max_updates(3_000_000)
        .max_sweeps(500)
        .run(g, &prog, apps::all_vertices(n))
        .unwrap_or_else(|e| panic!("{kind} over {} failed: {e}", transport.name()));
    let stats = exec.stats;
    let g = exec.graph;
    (g.vertex_ids().map(|v| g.vertex_data(v).rank).collect(), stats)
}

/// Assert two per-vertex value vectors agree within `tol` everywhere —
/// the fixed-point-comparison idiom every equivalence test shares.
pub fn assert_ranks_close(label: &str, oracle: &[f32], got: &[f32], tol: f32) {
    assert_eq!(oracle.len(), got.len(), "{label}: length mismatch");
    for (v, (a, b)) in oracle.iter().zip(got).enumerate() {
        assert!((a - b).abs() < tol, "{label} v{v}: oracle={a} got={b}");
    }
}
