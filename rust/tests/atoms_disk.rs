//! The on-disk atom store end to end: full-replay graph equality,
//! per-machine journal replay vs the in-memory local-graph build, and the
//! acceptance run — a locking-engine PageRank launched from `--atoms-dir`
//! reaching the same fixed point as the in-memory path.

use std::path::PathBuf;

use graphlab::apps::{self, pagerank};
use graphlab::distributed::LocalGraph;
use graphlab::engine::{Engine, EngineKind};
use graphlab::graph::{Graph, GraphBuilder, VertexId};
use graphlab::partition::atoms::{self, AtomSet};
use graphlab::util::Rng;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("graphlab-atoms-{tag}-{}", std::process::id()))
}

fn random_graph(n: usize, m: usize, seed: u64) -> Graph<u32, u64> {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new();
    b.add_vertices(n, |i| i as u32 * 3 + 1);
    let mut seen = std::collections::HashSet::new();
    let mut added = 0;
    while added < m {
        let u = rng.gen_range(n) as VertexId;
        let v = rng.gen_range(n) as VertexId;
        if u != v && seen.insert((u.min(v), u.max(v))) {
            b.add_edge(u, v, 1000 + added as u64);
            added += 1;
        }
    }
    b.build()
}

#[test]
fn full_replay_reproduces_the_graph_exactly() {
    let dir = tmp_dir("replay");
    for seed in 0..4 {
        let g = random_graph(150, 500, seed);
        let atom_set = AtomSet::grow_bfs(&g, 12, seed);
        atom_set.save_atoms(&g, &dir).unwrap();
        let (g2, store) = atoms::load_graph::<u32, u64>(&dir).unwrap();
        assert_eq!(store.num_vertices, g.num_vertices());
        assert_eq!(store.num_edges, g.num_edges());
        assert_eq!(g2.num_vertices(), g.num_vertices());
        assert_eq!(g2.num_edges(), g.num_edges());
        for v in g.vertex_ids() {
            assert_eq!(g2.vertex_data(v), g.vertex_data(v));
            // CSR adjacency must be bit-identical (local-graph replay
            // depends on the exact neighbor order).
            assert_eq!(g2.neighbors(v), g.neighbors(v), "seed={seed} v={v}");
        }
        for e in 0..g.num_edges() as u32 {
            assert_eq!(g2.edge_data(e), g.edge_data(e));
            assert_eq!(g2.endpoints(e), g.endpoints(e));
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn per_machine_replay_matches_in_memory_build() {
    let dir = tmp_dir("localgraph");
    for seed in 0..3 {
        let g = random_graph(200, 700, 100 + seed);
        let atom_set = AtomSet::grow_bfs(&g, 16, seed);
        atom_set.save_atoms(&g, &dir).unwrap();
        let store = atoms::AtomStore::open(&dir).unwrap();
        for machines in [2usize, 3, 5] {
            let (partition, placement) = store.place(machines);
            for m in 0..machines {
                let mem: LocalGraph<u32, u64> = LocalGraph::build(&g, &partition, m);
                let disk: LocalGraph<u32, u64> =
                    LocalGraph::from_atom_files(&dir, &placement.atom_to_machine, m).unwrap();
                let tag = format!("seed={seed} machines={machines} m={m}");
                assert_eq!(disk.machine, mem.machine, "{tag}");
                assert_eq!(disk.owned, mem.owned, "{tag}");
                assert_eq!(disk.l2g, mem.l2g, "{tag}");
                assert_eq!(disk.g2l, mem.g2l, "{tag}");
                assert_eq!(disk.owner, mem.owner, "{tag}");
                assert_eq!(disk.vdata, mem.vdata, "{tag}");
                assert_eq!(disk.vversion, mem.vversion, "{tag}");
                assert_eq!(disk.adj_offsets, mem.adj_offsets, "{tag}");
                assert_eq!(disk.adj, mem.adj, "{tag}");
                assert_eq!(disk.le2g, mem.le2g, "{tag}");
                assert_eq!(disk.ge2l, mem.ge2l, "{tag}");
                assert_eq!(disk.edata, mem.edata, "{tag}");
                assert_eq!(disk.eversion, mem.eversion, "{tag}");
                assert_eq!(disk.mirrors, mem.mirrors, "{tag}");
                assert_eq!(disk.edge_mirror, mem.edge_mirror, "{tag}");
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance criterion: a locking-engine PageRank launched with
/// `--atoms-dir` (every machine loads from disk) reaches the same fixed
/// point as the fully in-memory run.
#[test]
fn locking_engine_from_disk_atoms_matches_in_memory_fixed_point() {
    let n = 300;
    let edges = graphlab::datagen::web_graph(n, 6, 7);
    let prog = pagerank::PageRank {
        alpha: 0.15,
        eps: 1e-7,
        n,
        use_pjrt: false,
    };

    // In-memory path (default blocked partition).
    let g = pagerank::build(n, &edges, 0.15);
    let mem = Engine::new(EngineKind::Locking)
        .machines(2)
        .max_updates(400_000)
        .run(g, &prog, apps::all_vertices(n))
        .unwrap();

    // Disk path: persist atoms, reload the graph from the store, and run
    // with every machine replaying its own journals.
    let dir = tmp_dir("locking");
    let g = pagerank::build(n, &edges, 0.15);
    AtomSet::grow_bfs(&g, 16, 3).save_atoms(&g, &dir).unwrap();
    let (g_disk, _store) = atoms::load_graph::<pagerank::PrVertex, pagerank::PrEdge>(&dir).unwrap();
    let disk = Engine::new(EngineKind::Locking)
        .machines(2)
        .max_updates(400_000)
        .atoms_dir(&dir)
        .run(g_disk, &prog, apps::all_vertices(n))
        .unwrap();
    std::fs::remove_dir_all(&dir).ok();

    assert!(mem.stats.updates > n as u64, "in-memory run barely ran");
    assert!(disk.stats.updates > n as u64, "disk run barely ran");
    // The disk run crossed a real wire: encoded frame bytes were counted.
    assert!(
        disk.stats.total_bytes() > 0,
        "distributed run sent no bytes?"
    );
    for v in 0..n as VertexId {
        let a = mem.graph.vertex_data(v).rank;
        let b = disk.graph.vertex_data(v).rank;
        assert!(
            (a - b).abs() < 1e-4,
            "v{v}: in-memory={a} from-disk={b}"
        );
    }
}

/// The chromatic engine's schedule is deterministic given (coloring,
/// data), so the disk-loaded run must match an in-memory run over the
/// same store-derived partition exactly.
#[test]
fn chromatic_engine_from_disk_atoms_is_bit_identical() {
    let n = 200;
    let edges = graphlab::datagen::web_graph(n, 5, 11);
    let prog = pagerank::PageRank {
        alpha: 0.15,
        eps: 0.0,
        n,
        use_pjrt: false,
    };
    let dir = tmp_dir("chromatic");
    let g = pagerank::build(n, &edges, 0.15);
    AtomSet::grow_bfs(&g, 8, 2).save_atoms(&g, &dir).unwrap();
    let store = atoms::AtomStore::open(&dir).unwrap();
    let (partition, _placement) = store.place(3);

    let g_mem = pagerank::build(n, &edges, 0.15);
    let mem = Engine::new(EngineKind::Chromatic)
        .machines(3)
        .max_sweeps(4)
        .with_partition(partition)
        .run(g_mem, &prog, apps::all_vertices(n))
        .unwrap();

    let (g_disk, _) = atoms::load_graph::<pagerank::PrVertex, pagerank::PrEdge>(&dir).unwrap();
    let disk = Engine::new(EngineKind::Chromatic)
        .machines(3)
        .max_sweeps(4)
        .atoms_dir(&dir)
        .run(g_disk, &prog, apps::all_vertices(n))
        .unwrap();
    std::fs::remove_dir_all(&dir).ok();

    assert_eq!(mem.stats.updates, disk.stats.updates);
    for v in 0..n as VertexId {
        assert_eq!(
            mem.graph.vertex_data(v).rank.to_bits(),
            disk.graph.vertex_data(v).rank.to_bits(),
            "v{v}"
        );
    }
}

#[test]
fn atoms_dir_and_with_partition_conflict_is_an_error() {
    let dir = tmp_dir("conflict");
    let g = random_graph(40, 80, 1);
    AtomSet::grow_bfs(&g, 4, 1).save_atoms(&g, &dir).unwrap();

    struct Noop;
    impl graphlab::engine::VertexProgram<u32, u64> for Noop {
        fn update(
            &self,
            _scope: &mut graphlab::engine::Scope<u32, u64>,
            _ctx: &mut graphlab::engine::Ctx,
        ) {
        }
    }
    let res = Engine::new(EngineKind::Locking)
        .machines(2)
        .atoms_dir(&dir)
        .with_partition(graphlab::partition::Partition::blocked(40, 2))
        .run(g, &Noop, vec![]);
    assert!(res.is_err());

    // Wrong-sized graph against the store is also an error, not a panic.
    let g_small = random_graph(10, 12, 2);
    let res = Engine::new(EngineKind::Locking)
        .machines(2)
        .atoms_dir(&dir)
        .run(g_small, &Noop, vec![]);
    assert!(res.is_err());

    // Loading with the wrong data types fails up front with both type
    // names, not with a decode error mid-journal.
    let res = atoms::load_graph::<pagerank::PrVertex, pagerank::PrEdge>(&dir);
    assert!(res.is_err());
    assert!(
        format!("{:#}", res.unwrap_err()).contains("u32"),
        "error should name the stored type"
    );
    std::fs::remove_dir_all(&dir).ok();
}
