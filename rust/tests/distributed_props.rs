//! Property tests over the distributed substrate (seed-swept, in-repo
//! generators — no proptest crate offline).

use graphlab::graph::VertexId;
use graphlab::partition::{atoms, Coloring, Partition};
use graphlab::util::Rng;

mod common;
use common::random_graph;

#[test]
fn prop_greedy_coloring_always_valid() {
    for seed in 0..20 {
        let n = 50 + (seed as usize * 37) % 200;
        let m = n * 3;
        let g = random_graph(n, m, seed);
        let c = Coloring::greedy(&g);
        assert!(c.is_valid(&g), "seed={seed}");
        assert!(c.num_colors() as usize <= g.max_degree() + 1);
    }
}

#[test]
fn prop_second_order_coloring_always_distance2_valid() {
    for seed in 0..10 {
        let g = random_graph(60, 150, 1000 + seed);
        let c = Coloring::second_order(&g);
        assert!(c.is_second_order_valid(&g), "seed={seed}");
    }
}

#[test]
fn prop_two_phase_partition_covers_and_balances() {
    for seed in 0..10 {
        let g = random_graph(400, 1600, 2000 + seed);
        for machines in [2usize, 3, 8] {
            let p = atoms::two_phase(&g, 48, machines, seed);
            assert_eq!(p.num_vertices(), 400);
            let sizes = p.sizes();
            assert_eq!(sizes.iter().sum::<usize>(), 400);
            assert!(
                p.imbalance() < 2.0,
                "seed={seed} machines={machines} imbalance={}",
                p.imbalance()
            );
        }
    }
}

#[test]
fn prop_local_graphs_partition_ownership_exactly() {
    use graphlab::distributed::LocalGraph;
    for seed in 0..8 {
        let g = random_graph(120, 480, 3000 + seed);
        let p = Partition::random(120, 4, seed);
        let locals: Vec<LocalGraph<u32, u32>> =
            (0..4).map(|m| LocalGraph::build(&g, &p, m)).collect();
        // Ownership partition is exact.
        let total_owned: usize = locals.iter().map(|l| l.owned).sum();
        assert_eq!(total_owned, 120);
        for lg in &locals {
            // Every ghost is a neighbor of an owned vertex and owned
            // elsewhere.
            for lv in lg.owned..lg.l2g.len() {
                assert_ne!(lg.owner[lv], lg.machine);
            }
            // Mirrors point at machines that really ghost the vertex.
            for lv in 0..lg.owned {
                for &peer in &lg.mirrors[lv] {
                    let gv = lg.l2g[lv];
                    assert!(locals[peer].g2l.contains_key(&gv),
                        "machine {peer} should ghost vertex {gv}");
                }
            }
        }
    }
}

#[test]
fn prop_scheduler_task_conservation() {
    use graphlab::scheduler::{by_name, Task};
    for (si, name) in ["fifo", "priority", "multiqueue", "sweep"].iter().enumerate() {
        for seed in 0..6u64 {
            let mut rng = Rng::new(seed * 31 + si as u64);
            let n = 200;
            let mut s = by_name(name, n, seed).unwrap();
            let mut expected = std::collections::HashSet::new();
            for _ in 0..500 {
                let v = rng.gen_range(n) as VertexId;
                s.push(Task { vertex: v, priority: rng.f64() });
                expected.insert(v);
            }
            assert_eq!(s.len(), expected.len(), "{name} seed={seed}");
            let mut got = std::collections::HashSet::new();
            while let Some(t) = s.pop() {
                assert!(got.insert(t.vertex), "{name}: duplicate pop");
            }
            assert_eq!(got, expected, "{name} seed={seed}");
        }
    }
}

#[test]
fn prop_ghost_copies_coherent_after_chromatic_run() {
    // After a chromatic run, both machine copies of every cross edge and
    // every ghost must equal the owner's value. We verify through the
    // result graph (assembled from owner copies) by re-running: any
    // incoherence manifests as nondeterminism vs the 1-machine run.
    use graphlab::apps::{self, pagerank};
    use graphlab::engine::{Engine, EngineKind};
    for seed in 0..5 {
        let n = 150;
        let edges = graphlab::datagen::web_graph(n, 5, 100 + seed);
        let run = |machines: usize| {
            let g = pagerank::build(n, &edges, 0.15);
            let coloring = Coloring::greedy(&g);
            let partition = Partition::random(n, machines, seed);
            let prog = pagerank::PageRank { alpha: 0.15, eps: 0.0, n, use_pjrt: false };
            let exec = Engine::new(EngineKind::Chromatic)
                .machines(machines)
                .max_sweeps(4)
                .with_coloring(coloring)
                .with_partition(partition)
                .run(g, &prog, apps::all_vertices(n))
                .unwrap();
            let g = exec.graph;
            g.vertex_ids().map(|v| g.vertex_data(v).rank).collect::<Vec<f32>>()
        };
        let r1 = run(1);
        let r4 = run(4);
        for (a, b) in r1.iter().zip(&r4) {
            assert!((a - b).abs() < 1e-6, "seed={seed}: {a} vs {b}");
        }
    }
}
