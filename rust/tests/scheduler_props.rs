//! Property tests for the work-stealing scheduler
//! (`scheduler::work_stealing`): task conservation under concurrent
//! pushes/pops/steals, cross-queue dedup, approximate priority order, and
//! outstanding-work termination accounting.

use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::Barrier;

use graphlab::scheduler::{Policy, Task, WorkStealing};
use graphlab::util::Rng;

fn t(v: u32, p: f64) -> Task {
    Task { vertex: v, priority: p }
}

/// Concurrent pushers over *disjoint* vertex ranges racing concurrent
/// stealers: every task must be popped exactly once — none lost, none
/// duplicated — and the outstanding counter must drain to zero.
#[test]
fn prop_no_task_lost_or_duplicated_under_stealing() {
    for policy in [Policy::Fifo, Policy::Priority, Policy::MultiQueue] {
        for seed in 0..4u64 {
            let workers = 4usize;
            let per_worker = 500u32;
            let n = workers as u32 * per_worker;
            let ws = WorkStealing::new(policy, n as usize, workers, seed);
            let popped: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
            let total_popped = AtomicUsize::new(0);
            let barrier = Barrier::new(workers);

            std::thread::scope(|s| {
                for w in 0..workers {
                    let ws = &ws;
                    let popped = &popped;
                    let total_popped = &total_popped;
                    let barrier = &barrier;
                    s.spawn(move || {
                        let mut rng = Rng::new(seed ^ ((w as u64) << 32));
                        barrier.wait();
                        // Interleave pushes of our own disjoint range with
                        // pops (which may steal other ranges mid-push).
                        let lo = w as u32 * per_worker;
                        for v in lo..lo + per_worker {
                            ws.push(w, t(v, rng.f64()));
                            if v % 3 == 0 {
                                if let Some(task) = ws.pop(w, &mut rng) {
                                    popped[task.vertex as usize].fetch_add(1, Ordering::Relaxed);
                                    total_popped.fetch_add(1, Ordering::Relaxed);
                                    ws.task_done();
                                }
                            }
                        }
                        // Drain cooperatively until global quiescence.
                        loop {
                            match ws.pop(w, &mut rng) {
                                Some(task) => {
                                    popped[task.vertex as usize].fetch_add(1, Ordering::Relaxed);
                                    total_popped.fetch_add(1, Ordering::Relaxed);
                                    ws.task_done();
                                }
                                None => {
                                    if ws.outstanding() == 0 {
                                        break;
                                    }
                                    std::thread::yield_now();
                                }
                            }
                        }
                    });
                }
            });

            assert_eq!(
                total_popped.load(Ordering::Relaxed),
                n as usize,
                "{policy:?} seed={seed}: popped count"
            );
            for (v, c) in popped.iter().enumerate() {
                assert_eq!(
                    c.load(Ordering::Relaxed),
                    1,
                    "{policy:?} seed={seed}: vertex {v} popped {} times",
                    c.load(Ordering::Relaxed)
                );
            }
            assert_eq!(ws.outstanding(), 0, "{policy:?} seed={seed}");
        }
    }
}

/// Concurrent pushers all pushing the *same* vertex set: after the push
/// phase completes, draining must yield each vertex exactly once (global
/// dedup across per-worker queues, the `T ∪ T'` task-set semantics).
#[test]
fn prop_cross_queue_dedup_yields_each_vertex_once() {
    for seed in 0..4u64 {
        let workers = 4usize;
        let n = 300u32;
        let ws = WorkStealing::new(Policy::Priority, n as usize, workers, seed);
        let barrier = Barrier::new(workers);
        std::thread::scope(|s| {
            for w in 0..workers {
                let ws = &ws;
                let barrier = &barrier;
                s.spawn(move || {
                    let mut rng = Rng::new(seed * 17 + w as u64);
                    barrier.wait();
                    // Everyone pushes every vertex, shuffled order.
                    let mut verts: Vec<u32> = (0..n).collect();
                    rng.shuffle(&mut verts);
                    for v in verts {
                        ws.push(w, t(v, w as f64 + v as f64));
                    }
                });
            }
        });
        // No pops raced the pushes, so outstanding == distinct vertices.
        assert_eq!(ws.outstanding(), n as usize, "seed={seed}");
        let mut rng = Rng::new(9);
        let mut got: Vec<u32> = std::iter::from_fn(|| ws.pop(0, &mut rng))
            .map(|task| {
                ws.task_done();
                task.vertex
            })
            .collect();
        got.sort_unstable();
        assert_eq!(got, (0..n).collect::<Vec<_>>(), "seed={seed}");
        assert_eq!(ws.outstanding(), 0);
    }
}

/// Priority ordering is approximately respected across the pool: popping
/// everything from one worker (own queue + steals), the top-decile
/// priorities must surface early on average, and cross-queue merges keep
/// the maximum priority.
#[test]
fn prop_priority_order_approximately_respected() {
    let workers = 4usize;
    let n = 1000u32;
    let ws = WorkStealing::new(Policy::Priority, n as usize, workers, 3);
    for v in 0..n {
        // Scatter across queues like engine-local pushes would.
        ws.push((v % workers as u32) as usize, t(v, v as f64));
    }
    let mut rng = Rng::new(5);
    let order: Vec<f64> = std::iter::from_fn(|| ws.pop(1, &mut rng))
        .map(|task| {
            ws.task_done();
            task.priority
        })
        .collect();
    assert_eq!(order.len(), n as usize);
    let top_decile_mean_rank: f64 = order
        .iter()
        .enumerate()
        .filter(|(_, &p)| p >= 900.0)
        .map(|(i, _)| i as f64)
        .sum::<f64>()
        / 100.0;
    // Exact priority would give mean rank ~50; a random shuffle ~500.
    // Per-queue exact heaps + random-victim stealing sit well under 400.
    assert!(
        top_decile_mean_rank < 400.0,
        "mean rank of top decile = {top_decile_mean_rank}"
    );
}

/// Cross-queue merge keeps the max priority even when the re-push comes
/// from a different worker than the one homing the vertex.
#[test]
fn prop_merge_across_workers_keeps_max_priority() {
    let ws = WorkStealing::new(Policy::Priority, 64, 4, 0);
    let mut rng = Rng::new(1);
    for v in 0..64u32 {
        ws.push((v % 4) as usize, t(v, 1.0));
    }
    // Re-push everything from worker 3 with higher priority for even ids.
    for v in 0..64u32 {
        if v % 2 == 0 {
            ws.push(3, t(v, 100.0 + v as f64));
        }
    }
    assert_eq!(ws.outstanding(), 64);
    let mut popped: Vec<Task> = std::iter::from_fn(|| ws.pop(2, &mut rng))
        .map(|task| {
            ws.task_done();
            task
        })
        .collect();
    popped.sort_unstable_by_key(|task| task.vertex);
    for task in popped {
        if task.vertex % 2 == 0 {
            assert_eq!(task.priority, 100.0 + task.vertex as f64, "v{}", task.vertex);
        } else {
            assert_eq!(task.priority, 1.0, "v{}", task.vertex);
        }
    }
}
