//! Serving-mode properties (DESIGN.md §Serving): the client/peer RPC
//! grammar round-trips and decodes totally, a mutation batch against a
//! converged cluster re-converges **incrementally** (update counts well
//! under the initial convergence) to the same fixed point a from-scratch
//! run reaches on the mutated graph, and nothing a client sends — out of
//! range ids, self-loops, NaN weights, raw garbage bytes — can panic the
//! cluster: every failure is a typed [`ServeReply::Error`].
//!
//! The `#[ignore]`d smoke spawns real `graphlab serve` processes and a
//! real TCP client (CI cluster-smoke runs it with `--ignored`).

use std::collections::HashMap;
use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::time::Duration;

use graphlab::apps::pagerank::{self, PrEdge, PrVertex};
use graphlab::distributed::transport::{
    read_ack, read_reject_reason, write_handshake, ROLE_CLIENT, ROLE_WORKER,
};
use graphlab::graph::GraphBuilder;
use graphlab::partition::atoms::two_phase;
use graphlab::scheduler::Task;
use graphlab::serve::client::spawn_listener;
use graphlab::serve::engine::{ServeOpts, ServeSession};
use graphlab::serve::msg::{ErrorKind, Mutation, PeerMsg, RoutedMutation, ServeReply, ServeReq, ServeStats};
use graphlab::serve::{ServeClient, CLIENT_TAG};
use graphlab::util::Rng;
use graphlab::wire::{self, WIRE_VERSION};

// ---------------------------------------------------------------------------
// wire grammar: round-trips + totality
// ---------------------------------------------------------------------------

/// Round-trip plus prefix-totality (same contract as wire_props.rs):
/// decoding any strict prefix of the encoding must be an error.
fn assert_codec<W: wire::Wire + PartialEq + std::fmt::Debug>(v: &W) {
    let bytes = wire::to_bytes(v);
    let back: W = wire::from_bytes(&bytes).unwrap();
    assert_eq!(&back, v);
    for cut in 0..bytes.len() {
        assert!(
            wire::from_bytes::<W>(&bytes[..cut]).is_err(),
            "{cut}-byte prefix of a {}-byte encoding decoded",
            bytes.len()
        );
    }
}

fn sample_mutations() -> Vec<Mutation> {
    vec![
        Mutation::AddEdge { u: 3, v: 99, w: 0.125 },
        Mutation::RemoveEdge { u: 7, v: 2 },
        Mutation::SetEdgeWeight { u: 0, v: 1, w: -4.5 },
        Mutation::TouchVertex { v: 41 },
    ]
}

#[test]
fn prop_serve_client_grammar_round_trips() {
    for m in sample_mutations() {
        assert_codec(&m);
        assert_codec(&RoutedMutation { m, owner_u: 1, owner_v: 2 });
    }
    assert_codec(&ServeReq::Query { vertex: 17 });
    assert_codec(&ServeReq::Mutate { muts: sample_mutations() });
    assert_codec(&ServeReq::Mutate { muts: Vec::new() });
    assert_codec(&ServeReq::Stats);
    assert_codec(&ServeReq::Shutdown);

    let stats = ServeStats {
        epoch: 9,
        converged: true,
        initial_updates: 120_000,
        epoch_updates: 512,
        total_updates: 120_512,
        vertices: 20_000,
        edges: 81_234,
        machines: 3,
    };
    assert_codec(&stats);
    assert_codec(&ServeReply::Value { vertex: 17, rank: 0.031, epoch: 4, converged: false });
    assert_codec(&ServeReply::MutAck { epoch: 5, scheduled: 12, updates: 640, steps: 11 });
    assert_codec(&ServeReply::Stats(stats));
    assert_codec(&ServeReply::Bye);
    assert_codec(&ServeReply::Error {
        kind: ErrorKind::UnknownVertex,
        detail: "vertex 10000 out of range (n = 200)".to_string(),
    });
    assert_codec(&ServeReply::Error { kind: ErrorKind::BadRequest, detail: String::new() });
}

#[test]
fn prop_serve_peer_grammar_round_trips() {
    let routed: Vec<RoutedMutation> = sample_mutations()
        .into_iter()
        .map(|m| RoutedMutation { m, owner_u: 0, owner_v: 2 })
        .collect();
    assert_codec(&PeerMsg::Apply { epoch: 3, muts: routed });
    assert_codec(&PeerMsg::Apply { epoch: 0, muts: Vec::new() });
    assert_codec(&PeerMsg::Ghost {
        verts: vec![(4, 17, 0.25), (9, 1, -1.5)],
        tasks: vec![Task { vertex: 4, priority: 2.0 }, Task { vertex: 9, priority: 0.5 }],
    });
    assert_codec(&PeerMsg::StepEnd { step: 41 });
    assert_codec(&PeerMsg::Report { step: 41, pending: 7, updates: 1234 });
    assert_codec(&PeerMsg::Decision { step: 41, cont: true });
    assert_codec(&PeerMsg::Query { id: 77, vertex: 5 });
    assert_codec(&PeerMsg::Answer { id: 77, vertex: 5, rank: 0.01, version: 9 });
    assert_codec(&PeerMsg::Stop);
}

#[test]
fn prop_serve_decoding_is_total_on_garbage() {
    // Unknown discriminants are typed errors…
    assert!(wire::from_bytes::<Mutation>(&[200]).is_err());
    assert!(wire::from_bytes::<ServeReq>(&[200]).is_err());
    assert!(wire::from_bytes::<ServeReply>(&[200]).is_err());
    assert!(wire::from_bytes::<PeerMsg>(&[200]).is_err());
    // …and random byte soup never panics, whatever it decodes as.
    let mut rng = Rng::new(0x5e7e);
    for _ in 0..400 {
        let len = rng.gen_range(64);
        let buf: Vec<u8> = (0..len).map(|_| rng.gen_range(256) as u8).collect();
        let _ = wire::from_bytes::<Mutation>(&buf);
        let _ = wire::from_bytes::<ServeReq>(&buf);
        let _ = wire::from_bytes::<ServeReply>(&buf);
        let _ = wire::from_bytes::<PeerMsg>(&buf);
    }
}

// ---------------------------------------------------------------------------
// incremental recomputation vs from-scratch
// ---------------------------------------------------------------------------

fn rank_of(s: &ServeSession, v: u32) -> f32 {
    match s.query(v).expect("query") {
        ServeReply::Value { rank, .. } => rank,
        other => panic!("query {v} answered {other:?}"),
    }
}

/// The tentpole's acceptance criterion: converge a 3-machine serving
/// cluster, apply a batch of edge mutations, and require (a) the
/// re-convergence to be *incremental* — its update count a small
/// fraction of the initial convergence's — and (b) every queried rank to
/// match, within 1e-4, a from-scratch convergence on the mutated graph
/// (built directly, served by a fresh cluster with a different machine
/// count, so the fixed point is reached by a genuinely different path).
#[test]
fn incremental_reconvergence_matches_from_scratch() {
    let n = 1200usize;
    let edges = graphlab::datagen::web_graph(n, 6, 11);
    let g = pagerank::build(n, &edges, 0.15);
    let part = two_phase(&g, 24, 3, 7);
    let opts = ServeOpts { machines: 3, eps: 1e-7, ..ServeOpts::default() };
    let session = ServeSession::start(g, &part, &opts).expect("start serve cluster");
    let initial = session.wait_converged().expect("initial convergence");
    assert!(initial.converged && initial.initial_updates > 0);

    // Pick mutation targets with unambiguous semantics: pairs that occur
    // exactly once in the edge list (remove / reweight) and pairs not
    // present at all (add), so the oracle's replay is exact.
    let mut count: HashMap<(u32, u32), usize> = HashMap::new();
    for &(u, v) in &edges {
        *count.entry((u.min(v), u.max(v))).or_default() += 1;
    }
    let uniq: Vec<(u32, u32)> = edges
        .iter()
        .map(|&(u, v)| (u.min(v), u.max(v)))
        .filter(|k| count[k] == 1)
        .collect();
    assert!(uniq.len() >= 5, "generator produced too few unique edges");
    let mut absent = Vec::new();
    let mut probe = 0u32;
    while absent.len() < 2 {
        let cand = (probe, probe + n as u32 / 2);
        if cand.0 != cand.1 && !count.contains_key(&cand) {
            absent.push(cand);
        }
        probe += 1;
    }
    let muts = vec![
        Mutation::SetEdgeWeight { u: uniq[0].0, v: uniq[0].1, w: 0.05 },
        Mutation::SetEdgeWeight { u: uniq[1].1, v: uniq[1].0, w: 0.02 },
        Mutation::RemoveEdge { u: uniq[2].0, v: uniq[2].1 },
        Mutation::RemoveEdge { u: uniq[3].1, v: uniq[3].0 },
        Mutation::AddEdge { u: absent[0].0, v: absent[0].1, w: 0.05 },
        Mutation::AddEdge { u: absent[1].1, v: absent[1].0, w: 0.03 },
        Mutation::TouchVertex { v: uniq[4].0 },
    ];
    let ack = session.mutate(muts.clone()).expect("mutation batch");
    let (epoch, updates) = match ack {
        ServeReply::MutAck { epoch, scheduled, updates, .. } => {
            assert!(scheduled > 0);
            (epoch, updates)
        }
        other => panic!("mutation batch answered {other:?}"),
    };
    assert_eq!(epoch, 1, "first client batch is epoch 1 (epoch 0 = initial convergence)");
    assert!(updates > 0, "a structural batch must recompute something");
    // Incrementality: the dirtied-neighborhood recomputation touches a
    // small fraction of the work the initial convergence did.
    assert!(
        (updates as f64) < 0.2 * initial.initial_updates as f64,
        "re-convergence was not incremental: {updates} updates vs {} initially",
        initial.initial_updates
    );

    // Replies after the epoch carry a fresh staleness tag.
    match session.query(0).expect("query after mutation") {
        ServeReply::Value { epoch, converged, .. } => {
            assert_eq!(epoch, 1);
            assert!(converged, "no epoch in flight: the tag must say converged");
        }
        other => panic!("query answered {other:?}"),
    }

    // The from-scratch oracle: replay the serve mutation semantics on the
    // initial weighted edge list (pagerank::build weights; AddEdge and
    // SetEdgeWeight write weight w in both directions, RemoveEdge drops
    // the edge — serving never renormalizes degrees), then converge a
    // fresh cluster on the mutated graph from uniform ranks.
    let mut deg = vec![0u32; n];
    for &(u, v) in &edges {
        deg[u as usize] += 1;
        deg[v as usize] += 1;
    }
    let mut wedges: Vec<(u32, u32, f32, f32)> = edges
        .iter()
        .map(|&(u, v)| {
            let (lo, hi) = (u.min(v), u.max(v));
            (lo, hi, 0.85 / deg[hi as usize] as f32, 0.85 / deg[lo as usize] as f32)
        })
        .collect();
    for m in &muts {
        match *m {
            Mutation::AddEdge { u, v, w } => wedges.push((u.min(v), u.max(v), w, w)),
            Mutation::RemoveEdge { u, v } => {
                let (lo, hi) = (u.min(v), u.max(v));
                let pos = wedges
                    .iter()
                    .position(|&(a, b, _, _)| (a, b) == (lo, hi))
                    .expect("removed edge is unique by construction");
                wedges.remove(pos);
            }
            Mutation::SetEdgeWeight { u, v, w } => {
                let (lo, hi) = (u.min(v), u.max(v));
                let pos = wedges
                    .iter()
                    .position(|&(a, b, _, _)| (a, b) == (lo, hi))
                    .expect("reweighted edge is unique by construction");
                wedges[pos].2 = w;
                wedges[pos].3 = w;
            }
            Mutation::TouchVertex { .. } => {}
        }
    }
    let mut b = GraphBuilder::with_capacity(n, wedges.len());
    b.add_vertices(n, |_| PrVertex { rank: 1.0 / n as f32 });
    for &(lo, hi, to_lo, to_hi) in &wedges {
        b.add_edge(lo, hi, PrEdge { to_lo, to_hi });
    }
    let og = b.build();
    let opart = two_phase(&og, 16, 2, 3);
    let oracle = ServeSession::start(og, &opart, &ServeOpts { machines: 2, eps: 1e-7, ..ServeOpts::default() })
        .expect("start oracle cluster");
    oracle.wait_converged().expect("oracle convergence");

    for v in 0..n as u32 {
        let diff = (rank_of(&session, v) - rank_of(&oracle, v)).abs();
        assert!(
            diff <= 1e-4,
            "vertex {v}: incremental rank diverged from from-scratch by {diff}"
        );
    }
    oracle.shutdown().expect("oracle shutdown");
    session.shutdown().expect("serve shutdown");
}

// ---------------------------------------------------------------------------
// typed refusals: nothing a client sends panics the cluster
// ---------------------------------------------------------------------------

#[test]
fn bad_requests_get_typed_errors_not_panics() {
    let n = 60usize;
    let edges = graphlab::datagen::web_graph(n, 4, 5);
    let g = pagerank::build(n, &edges, 0.15);
    let part = two_phase(&g, 8, 2, 1);
    let opts = ServeOpts { eps: 1e-6, ..ServeOpts::default() };
    let session = ServeSession::start(g, &part, &opts).expect("start serve cluster");
    session.wait_converged().expect("initial convergence");

    match session.query(10_000).expect("query reply") {
        ServeReply::Error { kind: ErrorKind::UnknownVertex, .. } => {}
        other => panic!("out-of-range query answered {other:?}"),
    }
    match session.mutate(vec![Mutation::AddEdge { u: 2, v: 9_999, w: 0.1 }]).unwrap() {
        ServeReply::Error { kind: ErrorKind::UnknownVertex, .. } => {}
        other => panic!("out-of-range mutation answered {other:?}"),
    }
    match session.mutate(vec![Mutation::AddEdge { u: 3, v: 3, w: 0.1 }]).unwrap() {
        ServeReply::Error { kind: ErrorKind::BadRequest, detail } => {
            assert!(detail.contains("self-loop"), "refusal names the problem: {detail}")
        }
        other => panic!("self-loop mutation answered {other:?}"),
    }
    match session.mutate(vec![Mutation::SetEdgeWeight { u: 0, v: 1, w: f32::NAN }]).unwrap() {
        ServeReply::Error { kind: ErrorKind::BadRequest, .. } => {}
        other => panic!("NaN-weight mutation answered {other:?}"),
    }
    match session.mutate(vec![Mutation::TouchVertex { v: n as u32 }]).unwrap() {
        ServeReply::Error { kind: ErrorKind::UnknownVertex, .. } => {}
        other => panic!("out-of-range touch answered {other:?}"),
    }
    // A refusal wedges nothing: a valid batch still re-converges…
    match session.mutate(vec![Mutation::TouchVertex { v: 1 }]).unwrap() {
        ServeReply::MutAck { epoch: 1, .. } => {}
        other => panic!("valid touch after refusals answered {other:?}"),
    }
    // …and a valid query still answers.
    match session.query(1).unwrap() {
        ServeReply::Value { vertex: 1, .. } => {}
        other => panic!("valid query after refusals answered {other:?}"),
    }
    session.shutdown().expect("shutdown");
}

// ---------------------------------------------------------------------------
// the TCP client boundary
// ---------------------------------------------------------------------------

fn read_reply(s: &mut TcpStream) -> ServeReply {
    let mut len4 = [0u8; 4];
    s.read_exact(&mut len4).expect("reply length");
    let mut buf = vec![0u8; u32::from_le_bytes(len4) as usize];
    s.read_exact(&mut buf).expect("reply body");
    wire::from_bytes(&buf).expect("reply decodes")
}

fn write_req(s: &mut TcpStream, req: &ServeReq) {
    let body = wire::to_bytes(req);
    s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
    s.write_all(&body).unwrap();
    s.flush().unwrap();
}

/// Dial the client port raw and complete a valid serve handshake.
fn raw_client(addr: std::net::SocketAddr) -> TcpStream {
    let mut s = TcpStream::connect(addr).unwrap();
    write_handshake(&mut s, 0, 0, WIRE_VERSION, CLIENT_TAG, ROLE_CLIENT).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    assert!(read_ack(&mut s).unwrap(), "valid client handshake must be accepted");
    s
}

#[test]
fn tcp_client_boundary_is_total() {
    let n = 80usize;
    let edges = graphlab::datagen::web_graph(n, 4, 9);
    let g = pagerank::build(n, &edges, 0.15);
    let part = two_phase(&g, 8, 2, 1);
    let session =
        ServeSession::start(g, &part, &ServeOpts { eps: 1e-6, ..ServeOpts::default() }).unwrap();
    session.wait_converged().unwrap();
    let (addr, _accept) = spawn_listener("127.0.0.1:0", session.feed()).unwrap();

    // Happy path over real sockets.
    let mut c = ServeClient::connect(&addr.to_string()).expect("client connects");
    match c.query(3).unwrap() {
        ServeReply::Value { vertex: 3, rank, .. } => assert!(rank > 0.0),
        other => panic!("tcp query answered {other:?}"),
    }
    let st = c.stats().unwrap();
    assert_eq!((st.vertices, st.machines), (n as u64, 2));
    assert!(st.converged);

    // Worker-role connections are turned away with a reason, not framing
    // chaos — and so are wrong app tags.
    let mut w = TcpStream::connect(addr).unwrap();
    write_handshake(&mut w, 1, 2, WIRE_VERSION, CLIENT_TAG, ROLE_WORKER).unwrap();
    w.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert!(!read_ack(&mut w).unwrap_or(false), "worker role on client port must be rejected");
    let why = read_reject_reason(&mut w).expect("reject carries a reason");
    assert!(why.contains("client port"), "reason names the port: {why}");
    let mut t = TcpStream::connect(addr).unwrap();
    write_handshake(&mut t, 0, 0, WIRE_VERSION, "pagerank-msgs", ROLE_CLIENT).unwrap();
    t.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert!(!read_ack(&mut t).unwrap_or(false), "foreign tag on client port must be rejected");

    // Well-framed garbage: typed error, connection survives and still
    // serves valid requests afterwards.
    let mut raw = raw_client(addr);
    raw.write_all(&3u32.to_le_bytes()).unwrap();
    raw.write_all(&[0xff, 0xff, 0xff]).unwrap();
    raw.flush().unwrap();
    match read_reply(&mut raw) {
        ServeReply::Error { kind: ErrorKind::BadRequest, .. } => {}
        other => panic!("garbage frame answered {other:?}"),
    }
    write_req(&mut raw, &ServeReq::Stats);
    match read_reply(&mut raw) {
        ServeReply::Stats(s) => assert_eq!(s.vertices, n as u64),
        other => panic!("stats after garbage answered {other:?}"),
    }

    // A zero-length frame is a framing loss: best-effort typed error,
    // then the server hangs up.
    let mut broken = raw_client(addr);
    broken.write_all(&0u32.to_le_bytes()).unwrap();
    broken.flush().unwrap();
    match read_reply(&mut broken) {
        ServeReply::Error { kind: ErrorKind::BadRequest, detail } => {
            assert!(detail.contains("length"), "error names the framing problem: {detail}")
        }
        other => panic!("zero-length frame answered {other:?}"),
    }
    let mut one = [0u8; 1];
    assert!(
        matches!(broken.read(&mut one), Ok(0) | Err(_)),
        "connection must close after framing loss"
    );

    // The cluster survived all of it; shut down through the client.
    match c.shutdown().unwrap() {
        ServeReply::Bye => {}
        other => panic!("shutdown answered {other:?}"),
    }
    session.wait().expect("cluster drains cleanly");
}

// ---------------------------------------------------------------------------
// multi-process smoke (ignored by default; CI cluster-smoke runs it)
// ---------------------------------------------------------------------------

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

fn wait_with_deadline(
    child: &mut std::process::Child,
    secs: u64,
    who: &str,
) -> std::process::ExitStatus {
    let deadline = std::time::Instant::now() + Duration::from_secs(secs);
    loop {
        match child.try_wait().unwrap_or_else(|e| panic!("poll {who}: {e}")) {
            Some(s) => break s,
            None if std::time::Instant::now() > deadline => {
                child.kill().ok();
                child.wait().ok();
                panic!("{who} did not exit within {secs}s");
            }
            None => std::thread::sleep(Duration::from_millis(200)),
        }
    }
}

/// One attempt at the two-process serve cluster (retried on fresh ports).
fn try_serve_cluster(bin: &str, dir: &std::path::Path, atoms_s: &str) -> Result<(), String> {
    use std::process::{Command, Stdio};
    let hosts = dir.join("hosts.txt");
    std::fs::write(&hosts, format!("127.0.0.1:{}\n127.0.0.1:{}\n", free_port(), free_port()))
        .unwrap();
    let hosts_s = hosts.to_str().unwrap();
    let client_port = free_port();
    let listen = format!("127.0.0.1:{client_port}");

    let mut worker = Command::new(bin)
        .args(["serve", "--cluster", hosts_s, "--me", "1", "--atoms-dir", atoms_s])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve worker");
    let mut frontend = Command::new(bin)
        .args(["serve", "--cluster", hosts_s, "--me", "0", "--atoms-dir", atoms_s])
        .args(["--listen", &listen])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve frontend");

    let kill_both = |worker: &mut std::process::Child, frontend: &mut std::process::Child| {
        worker.kill().ok();
        worker.wait().ok();
        frontend.kill().ok();
        frontend.wait().ok();
    };

    // Dial the frontend until its listener is up (the cluster converges
    // in the background; queries are legal meanwhile).
    let mut client = None;
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    while client.is_none() {
        match ServeClient::connect(&listen) {
            Ok(c) => client = Some(c),
            Err(e) if std::time::Instant::now() > deadline => {
                kill_both(&mut worker, &mut frontend);
                return Err(format!("frontend never accepted a client: {e}"));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(200)),
        }
    }
    let mut client = client.unwrap();

    // Wait out the initial convergence via the stats RPC.
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    let initial = loop {
        match client.stats() {
            Ok(s) if s.converged => break s,
            Ok(_) => std::thread::sleep(Duration::from_millis(100)),
            Err(e) => {
                kill_both(&mut worker, &mut frontend);
                return Err(format!("stats RPC failed: {e}"));
            }
        }
        if std::time::Instant::now() > deadline {
            kill_both(&mut worker, &mut frontend);
            return Err("initial convergence did not finish within 120s".into());
        }
    };
    assert!(initial.initial_updates > 0, "converged with zero updates: {initial:?}");

    // A mutation batch over real TCP re-converges and acks.
    let ack = client
        .mutate(vec![
            Mutation::AddEdge { u: 11, v: 1777, w: 0.05 },
            Mutation::TouchVertex { v: 7 },
        ])
        .map_err(|e| format!("mutation RPC failed: {e}"))?;
    match ack {
        ServeReply::MutAck { epoch: 1, updates, .. } => {
            assert!(updates > 0, "mutation epoch recomputed nothing")
        }
        other => panic!("mutation batch answered {other:?}"),
    }
    match client.query(11).map_err(|e| format!("query RPC failed: {e}"))? {
        ServeReply::Value { vertex: 11, rank, epoch: 1, .. } => assert!(rank > 0.0),
        other => panic!("query answered {other:?}"),
    }

    // Client-driven shutdown stops every process cleanly.
    match client.shutdown().map_err(|e| format!("shutdown RPC failed: {e}"))? {
        ServeReply::Bye => {}
        other => panic!("shutdown answered {other:?}"),
    }
    let fs = wait_with_deadline(&mut frontend, 120, "serve frontend");
    assert!(fs.success(), "frontend exited with {fs}");
    let ws = wait_with_deadline(&mut worker, 120, "serve worker");
    assert!(ws.success(), "worker exited with {ws}");
    Ok(())
}

/// The serving path as real processes: `partition` once, launch machine 1
/// and the frontend as separate `graphlab serve --cluster` processes,
/// then drive query → mutate → re-converge → shutdown through a real TCP
/// `ServeClient`. Ports are picked by bind-and-release, so
/// connection-phase failures retry on fresh ports.
#[test]
#[ignore = "spawns real graphlab serve processes on loopback ports; run with --ignored (CI cluster-smoke)"]
fn multi_process_serve_smoke() {
    let bin = env!("CARGO_BIN_EXE_graphlab");
    let dir = std::env::temp_dir().join(format!("graphlab-serve-smoke-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let atoms = dir.join("atoms");
    let atoms_s = atoms.to_str().unwrap().to_string();
    let st = std::process::Command::new(bin)
        .args(["partition", "pagerank", "--atoms-dir", &atoms_s, "--n", "2000", "--atoms", "32"])
        .status()
        .expect("spawn graphlab partition");
    assert!(st.success(), "graphlab partition failed");

    let mut last_err = String::new();
    for attempt in 0..3 {
        match try_serve_cluster(bin, &dir, &atoms_s) {
            Ok(()) => {
                std::fs::remove_dir_all(&dir).ok();
                return;
            }
            Err(e) => {
                eprintln!("serve smoke attempt {attempt} failed, retrying on fresh ports: {e}");
                last_err = e;
            }
        }
    }
    panic!("serve smoke failed on 3 port sets; last error:\n{last_err}");
}
