//! Transport-layer properties: the TCP backend must be a drop-in
//! substrate under the engines (same fixed points, measured bytes), its
//! handshake must reject incompatible peers, and malformed bytes at the
//! socket boundary must surface as typed per-peer errors — never a
//! process abort.
//!
//! The PageRank tests here are the acceptance criterion for the
//! pluggable-transport refactor: a loopback-TCP run (real kernel
//! sockets, in-process harness) produces the same ranks as the
//! in-process channel transport within 1e-4, with `bytes_sent > 0` on
//! every machine. The `#[ignore]`d smoke goes one step further and
//! spawns actual `graphlab worker` / `graphlab run --cluster` processes
//! (CI's cluster-smoke job runs it with `--ignored`).

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use graphlab::apps::{self, pagerank};
use graphlab::distributed::network::{Endpoint, NetStats};
use graphlab::distributed::transport::{
    read_ack, read_handshake, write_handshake, TcpBound, TcpConfig,
};
use graphlab::distributed::TransportKind;
use graphlab::engine::{Engine, EngineKind};
use graphlab::wire::WIRE_VERSION;

/// Run PageRank to its fixed point on `kind` over `transport`, returning
/// the final ranks and the per-machine measured wire bytes.
fn pagerank_ranks(
    kind: EngineKind,
    transport: TransportKind,
    machines: usize,
    n: usize,
    edges: &[(u32, u32)],
) -> (Vec<f32>, Vec<u64>) {
    let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-7, n, use_pjrt: false };
    let g = pagerank::build(n, edges, 0.15);
    let exec = Engine::new(kind)
        .machines(machines)
        .transport(transport)
        .maxpending(128)
        .max_updates(3_000_000)
        .max_sweeps(500)
        .run(g, &prog, apps::all_vertices(n))
        .unwrap_or_else(|e| panic!("{kind} over {transport} failed: {e}"));
    let bytes = exec.stats.bytes_sent.clone();
    let g = exec.graph;
    (g.vertex_ids().map(|v| g.vertex_data(v).rank).collect(), bytes)
}

#[test]
fn tcp_loopback_chromatic_matches_inproc_pagerank() {
    let n = 400;
    let edges = graphlab::datagen::web_graph(n, 6, 17);
    for machines in [2usize, 4] {
        let (inproc, _) =
            pagerank_ranks(EngineKind::Chromatic, TransportKind::InProc, machines, n, &edges);
        let (tcp, bytes) =
            pagerank_ranks(EngineKind::Chromatic, TransportKind::Tcp, machines, n, &edges);
        for (v, (a, b)) in inproc.iter().zip(&tcp).enumerate() {
            assert!(
                (a - b).abs() < 1e-4,
                "chromatic x{machines} v{v}: inproc={a} tcp={b}"
            );
        }
        // Real sockets, real traffic: every machine measured sent bytes.
        assert_eq!(bytes.len(), machines);
        assert!(
            bytes.iter().all(|&b| b > 0),
            "chromatic x{machines}: a machine sent zero bytes over TCP: {bytes:?}"
        );
    }
}

#[test]
fn tcp_loopback_locking_matches_inproc_pagerank() {
    let n = 400;
    let edges = graphlab::datagen::web_graph(n, 6, 17);
    let (inproc, _) =
        pagerank_ranks(EngineKind::Locking, TransportKind::InProc, 3, n, &edges);
    let (tcp, bytes) = pagerank_ranks(EngineKind::Locking, TransportKind::Tcp, 3, n, &edges);
    for (v, (a, b)) in inproc.iter().zip(&tcp).enumerate() {
        assert!((a - b).abs() < 1e-4, "locking v{v}: inproc={a} tcp={b}");
    }
    assert!(
        bytes.iter().all(|&b| b > 0),
        "locking: a machine sent zero bytes over TCP: {bytes:?}"
    );
}

// ---------------------------------------------------------------------------
// handshake
// ---------------------------------------------------------------------------

#[test]
fn handshake_rejects_wrong_wire_version() {
    let bound = TcpBound::bind(0, "127.0.0.1:0", TcpConfig::new(2, "vtest")).unwrap();
    let mut s = TcpStream::connect(bound.local_addr()).unwrap();
    write_handshake(&mut s, 1, 2, WIRE_VERSION + 1, "vtest").unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // Rejected: explicit ack 0, or the acceptor closed the connection.
    assert!(!read_ack(&mut s).unwrap_or(false), "future wire version must be rejected");
}

#[test]
fn handshake_rejects_wrong_app_tag() {
    let bound = TcpBound::bind(0, "127.0.0.1:0", TcpConfig::new(2, "pagerank-msgs")).unwrap();
    let mut s = TcpStream::connect(bound.local_addr()).unwrap();
    write_handshake(&mut s, 1, 2, WIRE_VERSION, "als-msgs").unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert!(!read_ack(&mut s).unwrap_or(false), "foreign app tag must be rejected");
    // A matching handshake on a fresh connection still gets in: the
    // rejection did not wedge the acceptor.
    let mut ok = TcpStream::connect(bound.local_addr()).unwrap();
    write_handshake(&mut ok, 1, 2, WIRE_VERSION, "pagerank-msgs").unwrap();
    ok.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert!(read_ack(&mut ok).unwrap());
}

#[test]
fn handshake_rejects_wrong_cluster_size() {
    let bound = TcpBound::bind(0, "127.0.0.1:0", TcpConfig::new(2, "size")).unwrap();
    let mut s = TcpStream::connect(bound.local_addr()).unwrap();
    write_handshake(&mut s, 1, 5, WIRE_VERSION, "size").unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert!(!read_ack(&mut s).unwrap_or(false), "mismatched cluster size must be rejected");
}

// ---------------------------------------------------------------------------
// malformed frames at the socket boundary
// ---------------------------------------------------------------------------

/// Stand up a 2-machine "cluster" where machine 1 is a raw-socket puppet
/// the test drives by hand, returning machine 0's typed endpoint and the
/// puppet's two streams (inbound-to-0 for sending it bytes, and the
/// accepted outbound-from-0).
fn endpoint_with_puppet(tag: &str) -> (Endpoint<u32>, TcpStream, TcpStream) {
    let bound = TcpBound::bind(0, "127.0.0.1:0", TcpConfig::new(2, tag)).unwrap();
    let addr0 = bound.local_addr();
    let puppet_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr1 = puppet_listener.local_addr().unwrap();
    let tag_owned = tag.to_string();
    let puppet = std::thread::spawn(move || {
        // Accept machine 0's outbound connection and ack its handshake.
        let (mut from0, _) = puppet_listener.accept().unwrap();
        let hs = read_handshake(&mut from0).unwrap();
        assert_eq!((hs.sender, hs.machines), (0, 2));
        from0.write_all(&[1u8]).unwrap();
        // Open the inbound connection and handshake as machine 1.
        let mut to0 = TcpStream::connect(addr0).unwrap();
        write_handshake(&mut to0, 1, 2, WIRE_VERSION, &tag_owned).unwrap();
        to0.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        assert!(read_ack(&mut to0).unwrap());
        (to0, from0)
    });
    let transport = bound
        .connect(&[addr0.to_string(), addr1.to_string()])
        .expect("mesh with puppet");
    let (to0, from0) = puppet.join().unwrap();
    let stats: Arc<Vec<NetStats>> = Arc::new(vec![NetStats::default(), NetStats::default()]);
    (Endpoint::from_transport(Box::new(transport), stats), to0, from0)
}

#[test]
fn garbage_frame_is_a_typed_error_not_a_panic() {
    let (mut ep, mut to0, _from0) = endpoint_with_puppet("garbage");
    // A well-formed length prefix whose payload is not a valid u32
    // encoding (5 bytes: decode consumes 4, leaving trailing garbage).
    to0.write_all(&5u32.to_le_bytes()).unwrap();
    to0.write_all(&[0xff; 5]).unwrap();
    to0.flush().unwrap();
    // The frame must be swallowed (no message, no panic)…
    assert!(ep.recv_timeout(Duration::from_secs(2)).is_none());
    // …and surfaced as a typed error that disconnects the peer.
    let errs = ep.peer_errors();
    assert!(
        errs.iter().any(|e| e.peer == 1),
        "expected a typed error for peer 1, got {errs:?}"
    );
    assert!(!ep.peer_alive(1));
}

#[test]
fn truncated_stream_is_a_typed_error_not_a_panic() {
    let (mut ep, mut to0, _from0) = endpoint_with_puppet("truncated");
    // Claim an 80-byte payload, send 3, and vanish: the reader hits EOF
    // mid-frame.
    to0.write_all(&80u32.to_le_bytes()).unwrap();
    to0.write_all(&[1, 2, 3]).unwrap();
    to0.flush().unwrap();
    drop(to0);
    assert!(ep.recv_timeout(Duration::from_secs(2)).is_none());
    let errs = ep.peer_errors();
    assert!(
        errs.iter().any(|e| e.peer == 1),
        "expected a typed stream error for peer 1, got {errs:?}"
    );
}

#[test]
fn oversized_length_prefix_is_a_typed_error_not_an_allocation() {
    let (mut ep, mut to0, _from0) = endpoint_with_puppet("oversized");
    // A hostile length prefix (4 GiB): must be refused before allocation.
    to0.write_all(&u32::MAX.to_le_bytes()).unwrap();
    to0.flush().unwrap();
    assert!(ep.recv_timeout(Duration::from_secs(2)).is_none());
    let errs = ep.peer_errors();
    assert!(
        errs.iter().any(|e| e.peer == 1),
        "expected an oversized-frame error for peer 1, got {errs:?}"
    );
}

#[test]
fn valid_frames_still_flow_after_construction() {
    // Sanity check on the puppet harness itself: a correctly encoded
    // frame from the raw socket decodes into a typed message.
    let (mut ep, mut to0, _from0) = endpoint_with_puppet("valid");
    let payload = 0xDEADBEEFu32.to_le_bytes();
    to0.write_all(&4u32.to_le_bytes()).unwrap();
    to0.write_all(&payload).unwrap();
    to0.flush().unwrap();
    let got = ep.recv_timeout(Duration::from_secs(5)).expect("typed message");
    assert_eq!((got.src, got.msg), (1, 0xDEADBEEF));
    assert!(ep.peer_errors().is_empty());
}

// ---------------------------------------------------------------------------
// multi-process smoke (ignored by default; CI cluster-smoke runs it)
// ---------------------------------------------------------------------------

fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

/// One attempt at the two-process run: write a hosts file on fresh
/// ports, launch the worker, drive the cluster as machine 0, and check
/// both processes' results. Returns `Err` (instead of panicking) for
/// failures that a port-collision retry can fix.
fn try_cluster_run(bin: &str, dir: &std::path::Path, atoms_s: &str) -> Result<(), String> {
    use std::process::{Command, Stdio};
    let hosts = dir.join("hosts.txt");
    std::fs::write(&hosts, format!("127.0.0.1:{}\n127.0.0.1:{}\n", free_port(), free_port()))
        .unwrap();
    let hosts_s = hosts.to_str().unwrap();

    // Launch machine 1 as a real worker process…
    let mut worker = Command::new(bin)
        .args(["worker", "--me", "1", "--hosts", hosts_s, "--atoms-dir", atoms_s])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn graphlab worker");

    // …and drive the run as machine 0.
    let out = Command::new(bin)
        .args(["run", "pagerank", "--cluster", hosts_s, "--atoms-dir", atoms_s])
        .output()
        .expect("spawn graphlab run --cluster");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    let stderr = String::from_utf8_lossy(&out.stderr).to_string();
    if !out.status.success() {
        worker.kill().ok();
        worker.wait().ok();
        return Err(format!("driver failed:\n{stdout}\n{stderr}"));
    }
    if !stdout.contains("done (machine 0)") {
        worker.kill().ok();
        worker.wait().ok();
        return Err(format!("driver did not report per-machine completion:\n{stdout}"));
    }
    // Measured traffic crossed a process boundary: parse the number
    // before the word "bytes" on the completion line.
    let bytes: u64 = stdout
        .lines()
        .find(|l| l.contains("bytes sent"))
        .map(|l| {
            let toks: Vec<&str> = l.split_whitespace().collect();
            toks.iter()
                .position(|&t| t == "bytes")
                .and_then(|i| i.checked_sub(1))
                .and_then(|i| toks[i].parse().ok())
                .unwrap_or(0)
        })
        .unwrap_or(0);
    assert!(bytes > 0, "driver reported zero wire bytes:\n{stdout}");

    // The worker must terminate cleanly on its own.
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    let status = loop {
        match worker.try_wait().expect("poll worker") {
            Some(s) => break s,
            None if std::time::Instant::now() > deadline => {
                worker.kill().ok();
                worker.wait().ok();
                panic!("worker did not exit within 120s");
            }
            None => std::thread::sleep(Duration::from_millis(200)),
        }
    };
    assert!(status.success(), "worker exited with {status}");
    Ok(())
}

/// The paper's startup path as real processes: `partition` once, launch a
/// `worker`, then `run --cluster` as machine 0 — both processes replay
/// only their own atom journals and speak the chromatic protocol over
/// loopback TCP. Ports are picked by bind-and-release, which can race
/// with other processes on a busy host, so connection-phase failures are
/// retried on fresh ports.
#[test]
#[ignore = "spawns real graphlab processes on loopback ports; run with --ignored (CI cluster-smoke)"]
fn multi_process_worker_smoke() {
    use std::process::Command;
    let bin = env!("CARGO_BIN_EXE_graphlab");
    let dir = std::env::temp_dir().join(format!("graphlab-cluster-smoke-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let atoms = dir.join("atoms");
    let atoms_s = atoms.to_str().unwrap().to_string();

    // Partition once: one atom store feeds every process and attempt.
    let st = Command::new(bin)
        .args(["partition", "pagerank", "--atoms-dir", &atoms_s, "--n", "2000", "--atoms", "32"])
        .status()
        .expect("spawn graphlab partition");
    assert!(st.success(), "graphlab partition failed");

    let mut last_err = String::new();
    for attempt in 0..3 {
        match try_cluster_run(bin, &dir, &atoms_s) {
            Ok(()) => {
                std::fs::remove_dir_all(&dir).ok();
                return;
            }
            Err(e) => {
                eprintln!("cluster smoke attempt {attempt} failed, retrying on fresh ports: {e}");
                last_err = e;
            }
        }
    }
    panic!("cluster smoke failed on 3 port sets; last error:\n{last_err}");
}
