//! Transport-layer properties: the TCP backend must be a drop-in
//! substrate under the engines (same fixed points, measured bytes), its
//! handshake must reject incompatible peers, and malformed bytes at the
//! socket boundary must surface as typed per-peer errors — never a
//! process abort.
//!
//! The PageRank tests here are the acceptance criterion for the
//! pluggable-transport refactor: a loopback-TCP run (real kernel
//! sockets, in-process harness) produces the same ranks as the
//! in-process channel transport within 1e-4, with `bytes_sent > 0` on
//! every machine. The `#[ignore]`d smoke goes one step further and
//! spawns actual `graphlab worker` / `graphlab run --cluster` processes
//! (CI's cluster-smoke job runs it with `--ignored`).

use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use graphlab::distributed::network::{Endpoint, NetStats};
use graphlab::distributed::transport::{
    read_ack, read_handshake, write_handshake, TcpBound, TcpConfig, ROLE_WORKER,
};
use graphlab::distributed::{Network, TransportKind};
use graphlab::engine::EngineKind;
use graphlab::wire::WIRE_VERSION;

mod common;
use common::assert_ranks_close;

/// Run PageRank to its fixed point on `kind` over `transport`, returning
/// the final ranks and the per-machine measured wire bytes.
fn pagerank_ranks(
    kind: EngineKind,
    transport: TransportKind,
    machines: usize,
    n: usize,
    edges: &[(u32, u32)],
) -> (Vec<f32>, Vec<u64>) {
    let (ranks, stats) = common::pagerank_fixed_point(kind, transport, machines, n, edges, 1e-7);
    (ranks, stats.bytes_sent)
}

#[test]
fn tcp_loopback_chromatic_matches_inproc_pagerank() {
    let n = 400;
    let edges = graphlab::datagen::web_graph(n, 6, 17);
    for machines in [2usize, 4] {
        let (inproc, _) =
            pagerank_ranks(EngineKind::Chromatic, TransportKind::InProc, machines, n, &edges);
        let (tcp, bytes) =
            pagerank_ranks(EngineKind::Chromatic, TransportKind::Tcp, machines, n, &edges);
        assert_ranks_close(&format!("chromatic x{machines} tcp"), &inproc, &tcp, 1e-4);
        // Real sockets, real traffic: every machine measured sent bytes.
        assert_eq!(bytes.len(), machines);
        assert!(
            bytes.iter().all(|&b| b > 0),
            "chromatic x{machines}: a machine sent zero bytes over TCP: {bytes:?}"
        );
    }
}

#[test]
fn tcp_loopback_locking_matches_inproc_pagerank() {
    let n = 400;
    let edges = graphlab::datagen::web_graph(n, 6, 17);
    let (inproc, _) =
        pagerank_ranks(EngineKind::Locking, TransportKind::InProc, 3, n, &edges);
    let (tcp, bytes) = pagerank_ranks(EngineKind::Locking, TransportKind::Tcp, 3, n, &edges);
    assert_ranks_close("locking tcp", &inproc, &tcp, 1e-4);
    assert!(
        bytes.iter().all(|&b| b > 0),
        "locking: a machine sent zero bytes over TCP: {bytes:?}"
    );
}

// ---------------------------------------------------------------------------
// handshake
// ---------------------------------------------------------------------------

#[test]
fn handshake_rejects_wrong_wire_version() {
    let bound = TcpBound::bind(0, "127.0.0.1:0", TcpConfig::new(2, "vtest")).unwrap();
    let mut s = TcpStream::connect(bound.local_addr()).unwrap();
    write_handshake(&mut s, 1, 2, WIRE_VERSION + 1, "vtest", ROLE_WORKER).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // Rejected: explicit ack 0, or the acceptor closed the connection.
    assert!(!read_ack(&mut s).unwrap_or(false), "future wire version must be rejected");
}

#[test]
fn handshake_rejects_wrong_app_tag() {
    let bound = TcpBound::bind(0, "127.0.0.1:0", TcpConfig::new(2, "pagerank-msgs")).unwrap();
    let mut s = TcpStream::connect(bound.local_addr()).unwrap();
    write_handshake(&mut s, 1, 2, WIRE_VERSION, "als-msgs", ROLE_WORKER).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert!(!read_ack(&mut s).unwrap_or(false), "foreign app tag must be rejected");
    // A matching handshake on a fresh connection still gets in: the
    // rejection did not wedge the acceptor.
    let mut ok = TcpStream::connect(bound.local_addr()).unwrap();
    write_handshake(&mut ok, 1, 2, WIRE_VERSION, "pagerank-msgs", ROLE_WORKER).unwrap();
    ok.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert!(read_ack(&mut ok).unwrap());
}

#[test]
fn handshake_rejects_wrong_cluster_size() {
    let bound = TcpBound::bind(0, "127.0.0.1:0", TcpConfig::new(2, "size")).unwrap();
    let mut s = TcpStream::connect(bound.local_addr()).unwrap();
    write_handshake(&mut s, 1, 5, WIRE_VERSION, "size", ROLE_WORKER).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert!(!read_ack(&mut s).unwrap_or(false), "mismatched cluster size must be rejected");
}

// ---------------------------------------------------------------------------
// malformed frames at the socket boundary
// ---------------------------------------------------------------------------

/// Stand up a 2-machine "cluster" where machine 1 is a raw-socket puppet
/// the test drives by hand, returning machine 0's typed endpoint and the
/// puppet's two streams (inbound-to-0 for sending it bytes, and the
/// accepted outbound-from-0).
fn endpoint_with_puppet(tag: &str) -> (Endpoint<u32>, TcpStream, TcpStream) {
    let bound = TcpBound::bind(0, "127.0.0.1:0", TcpConfig::new(2, tag)).unwrap();
    let addr0 = bound.local_addr();
    let puppet_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr1 = puppet_listener.local_addr().unwrap();
    let tag_owned = tag.to_string();
    let puppet = std::thread::spawn(move || {
        // Accept machine 0's outbound connection and ack its handshake.
        let (mut from0, _) = puppet_listener.accept().unwrap();
        let hs = read_handshake(&mut from0).unwrap();
        assert_eq!((hs.sender, hs.machines), (0, 2));
        from0.write_all(&[1u8]).unwrap();
        // Open the inbound connection and handshake as machine 1.
        let mut to0 = TcpStream::connect(addr0).unwrap();
        write_handshake(&mut to0, 1, 2, WIRE_VERSION, &tag_owned, ROLE_WORKER).unwrap();
        to0.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        assert!(read_ack(&mut to0).unwrap());
        (to0, from0)
    });
    let transport = bound
        .connect(&[addr0.to_string(), addr1.to_string()])
        .expect("mesh with puppet");
    let (to0, from0) = puppet.join().unwrap();
    let stats: Arc<Vec<NetStats>> = Arc::new(vec![NetStats::default(), NetStats::default()]);
    (Endpoint::from_transport(Box::new(transport), stats), to0, from0)
}

#[test]
fn garbage_frame_is_a_typed_error_not_a_panic() {
    let (mut ep, mut to0, _from0) = endpoint_with_puppet("garbage");
    // A well-formed length prefix whose payload is not a valid u32
    // encoding (5 bytes: decode consumes 4, leaving trailing garbage).
    to0.write_all(&5u32.to_le_bytes()).unwrap();
    to0.write_all(&[0xff; 5]).unwrap();
    to0.flush().unwrap();
    // The frame must be swallowed (no message, no panic)…
    assert!(ep.recv_timeout(Duration::from_secs(2)).is_none());
    // …and surfaced as a typed error that disconnects the peer.
    let errs = ep.peer_errors();
    assert!(
        errs.iter().any(|e| e.peer == 1),
        "expected a typed error for peer 1, got {errs:?}"
    );
    assert!(!ep.peer_alive(1));
}

#[test]
fn truncated_stream_is_a_typed_error_not_a_panic() {
    let (mut ep, mut to0, _from0) = endpoint_with_puppet("truncated");
    // Claim an 80-byte payload, send 3, and vanish: the reader hits EOF
    // mid-frame.
    to0.write_all(&80u32.to_le_bytes()).unwrap();
    to0.write_all(&[1, 2, 3]).unwrap();
    to0.flush().unwrap();
    drop(to0);
    assert!(ep.recv_timeout(Duration::from_secs(2)).is_none());
    let errs = ep.peer_errors();
    assert!(
        errs.iter().any(|e| e.peer == 1),
        "expected a typed stream error for peer 1, got {errs:?}"
    );
}

#[test]
fn oversized_length_prefix_is_a_typed_error_not_an_allocation() {
    let (mut ep, mut to0, _from0) = endpoint_with_puppet("oversized");
    // A hostile length prefix (4 GiB): must be refused before allocation.
    to0.write_all(&u32::MAX.to_le_bytes()).unwrap();
    to0.flush().unwrap();
    assert!(ep.recv_timeout(Duration::from_secs(2)).is_none());
    let errs = ep.peer_errors();
    assert!(
        errs.iter().any(|e| e.peer == 1),
        "expected an oversized-frame error for peer 1, got {errs:?}"
    );
}

#[test]
fn valid_frames_still_flow_after_construction() {
    // Sanity check on the puppet harness itself: a correctly encoded
    // frame from the raw socket decodes into a typed message.
    let (mut ep, mut to0, _from0) = endpoint_with_puppet("valid");
    let payload = 0xDEADBEEFu32.to_le_bytes();
    to0.write_all(&4u32.to_le_bytes()).unwrap();
    to0.write_all(&payload).unwrap();
    to0.flush().unwrap();
    let got = ep.recv_timeout(Duration::from_secs(5)).expect("typed message");
    assert_eq!((got.src, got.msg), (1, 0xDEADBEEF));
    assert!(ep.peer_errors().is_empty());
}

// ---------------------------------------------------------------------------
// batched sends: coalescing must not change accounting, order, or decoding
// ---------------------------------------------------------------------------

/// Byte/message accounting parity: the same message stream sent one
/// frame at a time and sent through `send_batch` (multi-frame buffers,
/// coalesced by the writer thread) must meter identical `bytes_sent` /
/// `msgs_sent` — accounting is per logical message at encode time, never
/// per write. The received streams must also be identical: multi-frame
/// buffers decode to the same typed messages in the same order.
#[test]
fn coalesced_batches_account_identical_bytes_and_msgs() {
    let msgs: Vec<u32> = (0..96u32).map(|i| i * 31 + 7).collect();
    let run = |batched: bool| -> (u64, u64, Vec<u32>) {
        let net: Network<u32> = Network::tcp_loopback(2).unwrap();
        let mut eps = net.into_endpoints();
        let mut ep1 = eps.pop().unwrap();
        let ep0 = eps.pop().unwrap();
        if batched {
            for chunk in msgs.chunks(32) {
                ep0.send_batch(1, chunk.to_vec());
            }
        } else {
            for &m in &msgs {
                ep0.send(1, m);
            }
        }
        let mut got = Vec::with_capacity(msgs.len());
        while got.len() < msgs.len() {
            got.push(ep1.recv_timeout(Duration::from_secs(10)).expect("message lost").msg);
        }
        let s = &ep0.stats()[0];
        (s.bytes_sent.load(Ordering::Relaxed), s.msgs_sent.load(Ordering::Relaxed), got)
    };
    let (bytes_per_frame, msgs_per_frame, got_per_frame) = run(false);
    let (bytes_batched, msgs_batched, got_batched) = run(true);
    assert_eq!(
        (bytes_per_frame, msgs_per_frame),
        (bytes_batched, msgs_batched),
        "coalescing changed the meters"
    );
    assert_eq!(got_per_frame, got_batched, "coalescing changed the received stream");
    assert_eq!(got_per_frame, msgs, "stream did not round-trip");
}

/// FIFO across the coalescing boundary: singles and batches interleaved
/// on one peer arrive in exactly the submission order.
#[test]
fn fifo_order_survives_interleaved_sends_and_batches() {
    let net: Network<u32> = Network::tcp_loopback(2).unwrap();
    let mut eps = net.into_endpoints();
    let mut ep1 = eps.pop().unwrap();
    let ep0 = eps.pop().unwrap();
    ep0.send(1, 0);
    ep0.send_batch(1, vec![1, 2, 3]);
    ep0.send(1, 4);
    ep0.send_batch(1, vec![5, 6]);
    let mut got = Vec::new();
    while got.len() < 7 {
        got.push(ep1.recv_timeout(Duration::from_secs(10)).expect("message lost").msg);
    }
    assert_eq!(got, vec![0, 1, 2, 3, 4, 5, 6]);
}

/// Wire shape of a batch: `send_batch` emits ordinary back-to-back
/// `[u32 len][payload]` frames — a receiver that knows nothing about
/// batching parses the stream unchanged.
#[test]
fn batched_buffer_is_back_to_back_frames_on_the_wire() {
    let (ep, _to0, mut from0) = endpoint_with_puppet("batch-wire");
    ep.send_batch(1, vec![0xAAu32, 0xBB, 0xCC]);
    from0.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 24];
    from0.read_exact(&mut buf).unwrap();
    for (i, want) in [0xAAu32, 0xBB, 0xCC].iter().enumerate() {
        let off = i * 8;
        let len = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
        let val = u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap());
        assert_eq!((len, val), (4, *want), "frame {i} malformed on the wire");
    }
}

/// Hostile cut mid-batch: a peer that dies between the frames of a
/// coalesced buffer delivers its complete leading frames and surfaces
/// the torn tail as a typed per-peer error — never a panic.
#[test]
fn stream_cut_mid_batch_yields_messages_then_typed_error() {
    let (mut ep, mut to0, _from0) = endpoint_with_puppet("cut-mid-batch");
    // Two frames in one write: frame 1 complete, frame 2 claims 4
    // payload bytes but delivers 2, then the connection drops.
    let mut batch = Vec::new();
    batch.extend_from_slice(&4u32.to_le_bytes());
    batch.extend_from_slice(&7u32.to_le_bytes());
    batch.extend_from_slice(&4u32.to_le_bytes());
    batch.extend_from_slice(&[9, 9]);
    to0.write_all(&batch).unwrap();
    to0.flush().unwrap();
    drop(to0);
    let got = ep.recv_timeout(Duration::from_secs(5)).expect("leading frame lost");
    assert_eq!((got.src, got.msg), (1, 7));
    assert!(ep.recv_timeout(Duration::from_secs(2)).is_none());
    let errs = ep.peer_errors();
    assert!(
        errs.iter().any(|e| e.peer == 1),
        "expected a typed mid-batch error for peer 1, got {errs:?}"
    );
    assert!(!ep.peer_alive(1));
}

// ---------------------------------------------------------------------------
// multi-process smoke (ignored by default; CI cluster-smoke runs it)
// ---------------------------------------------------------------------------

fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

/// The final cluster-wide sync value every `graphlab run`/`worker`
/// process prints as `probe <key>=<value>` — the machine-parseable
/// result line the smoke tests diff against an in-process oracle.
fn parse_probe(stdout: &str) -> Option<f64> {
    stdout
        .lines()
        .rev()
        .find_map(|l| l.strip_prefix("probe total_rank=")?.trim().parse().ok())
}

/// The per-machine sent-byte count from a `done (machine N): …` line:
/// the number right before the word "bytes".
fn parse_done_bytes(stdout: &str) -> u64 {
    stdout
        .lines()
        .find(|l| l.contains("bytes sent"))
        .map(|l| {
            let toks: Vec<&str> = l.split_whitespace().collect();
            toks.iter()
                .position(|&t| t == "bytes")
                .and_then(|i| i.checked_sub(1))
                .and_then(|i| toks[i].parse().ok())
                .unwrap_or(0)
        })
        .unwrap_or(0)
}

/// Poll a child until it exits or `secs` elapse (kill on timeout).
fn wait_with_deadline(child: &mut std::process::Child, secs: u64, who: &str) -> std::process::ExitStatus {
    let deadline = std::time::Instant::now() + Duration::from_secs(secs);
    loop {
        match child.try_wait().unwrap_or_else(|e| panic!("poll {who}: {e}")) {
            Some(s) => break s,
            None if std::time::Instant::now() > deadline => {
                child.kill().ok();
                child.wait().ok();
                panic!("{who} did not exit within {secs}s");
            }
            None => std::thread::sleep(Duration::from_millis(200)),
        }
    }
}

/// One attempt at the two-process run: write a hosts file on fresh
/// ports, launch the worker, drive the cluster as machine 0, and check
/// both processes' results against the in-process `oracle` probe value.
/// Returns `Err` (instead of panicking) for failures that a
/// port-collision retry can fix.
fn try_cluster_run(
    bin: &str,
    dir: &std::path::Path,
    atoms_s: &str,
    oracle: f64,
) -> Result<(), String> {
    use std::process::{Command, Stdio};
    let hosts = dir.join("hosts.txt");
    std::fs::write(&hosts, format!("127.0.0.1:{}\n127.0.0.1:{}\n", free_port(), free_port()))
        .unwrap();
    let hosts_s = hosts.to_str().unwrap();

    // Launch machine 1 as a real worker process…
    let mut worker = Command::new(bin)
        .args(["worker", "--me", "1", "--hosts", hosts_s, "--atoms-dir", atoms_s])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn graphlab worker");

    // …and drive the run as machine 0.
    let out = Command::new(bin)
        .args(["run", "pagerank", "--cluster", hosts_s, "--atoms-dir", atoms_s])
        .output()
        .expect("spawn graphlab run --cluster");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    let stderr = String::from_utf8_lossy(&out.stderr).to_string();
    if !out.status.success() {
        worker.kill().ok();
        worker.wait().ok();
        return Err(format!("driver failed:\n{stdout}\n{stderr}"));
    }
    if !stdout.contains("done (machine 0)") {
        worker.kill().ok();
        worker.wait().ok();
        return Err(format!("driver did not report per-machine completion:\n{stdout}"));
    }
    // Result equality vs the in-process oracle: the chromatic schedule is
    // deterministic and global syncs reduce in machine order, so the
    // cluster's final sync value matches the in-process run's.
    let probe = parse_probe(&stdout)
        .unwrap_or_else(|| panic!("driver printed no probe line:\n{stdout}"));
    assert!(
        (probe - oracle).abs() < 1e-6 * oracle.abs().max(1.0),
        "cluster result diverged from in-process oracle: {probe} vs {oracle}"
    );
    // Measured traffic crossed a process boundary on the driver's side…
    let bytes0 = parse_done_bytes(&stdout);
    assert!(bytes0 > 0, "driver reported zero wire bytes:\n{stdout}");

    // The worker must terminate cleanly on its own.
    let status = wait_with_deadline(&mut worker, 120, "worker");
    assert!(status.success(), "worker exited with {status}");
    // …and on the worker's side too, with the same cluster-wide result.
    let wout = worker.wait_with_output().expect("collect worker output");
    let wstdout = String::from_utf8_lossy(&wout.stdout).to_string();
    let bytes1 = parse_done_bytes(&wstdout);
    assert!(bytes1 > 0, "worker reported zero wire bytes:\n{wstdout}");
    let wprobe = parse_probe(&wstdout)
        .unwrap_or_else(|| panic!("worker printed no probe line:\n{wstdout}"));
    assert!(
        (wprobe - oracle).abs() < 1e-6 * oracle.abs().max(1.0),
        "worker result diverged from in-process oracle: {wprobe} vs {oracle}"
    );
    Ok(())
}

/// Write the shared atom store once and compute the in-process oracle
/// probe value for the given extra CLI args (e.g. `--sweeps 400`).
fn prepare_store_and_oracle(
    bin: &str,
    dir: &std::path::Path,
    extra: &[&str],
) -> (String, f64) {
    use std::process::Command;
    let atoms = dir.join("atoms");
    let atoms_s = atoms.to_str().unwrap().to_string();
    let st = Command::new(bin)
        .args(["partition", "pagerank", "--atoms-dir", &atoms_s, "--n", "2000", "--atoms", "32"])
        .status()
        .expect("spawn graphlab partition");
    assert!(st.success(), "graphlab partition failed");
    // The oracle: the identical run, in one process (2 in-proc machines).
    let out = Command::new(bin)
        .args(["run", "pagerank", "--atoms-dir", &atoms_s, "--machines", "2"])
        .args(extra)
        .output()
        .expect("spawn in-process oracle run");
    assert!(
        out.status.success(),
        "oracle run failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    let oracle =
        parse_probe(&stdout).unwrap_or_else(|| panic!("oracle printed no probe line:\n{stdout}"));
    (atoms_s, oracle)
}

/// The paper's startup path as real processes: `partition` once, launch a
/// `worker`, then `run --cluster` as machine 0 — both processes replay
/// only their own atom journals and speak the chromatic protocol over
/// loopback TCP, and both must reproduce the in-process oracle's result
/// with nonzero measured wire traffic. Ports are picked by
/// bind-and-release, which can race with other processes on a busy host,
/// so connection-phase failures are retried on fresh ports.
#[test]
#[ignore = "spawns real graphlab processes on loopback ports; run with --ignored (CI cluster-smoke)"]
fn multi_process_worker_smoke() {
    let bin = env!("CARGO_BIN_EXE_graphlab");
    let dir = std::env::temp_dir().join(format!("graphlab-cluster-smoke-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (atoms_s, oracle) = prepare_store_and_oracle(bin, &dir, &[]);

    let mut last_err = String::new();
    for attempt in 0..3 {
        match try_cluster_run(bin, &dir, &atoms_s, oracle) {
            Ok(()) => {
                std::fs::remove_dir_all(&dir).ok();
                return;
            }
            Err(e) => {
                eprintln!("cluster smoke attempt {attempt} failed, retrying on fresh ports: {e}");
                last_err = e;
            }
        }
    }
    panic!("cluster smoke failed on 3 port sets; last error:\n{last_err}");
}

/// True once `root` holds a snapshot directory with every machine's
/// committed part file.
fn has_complete_snapshot(root: &std::path::Path, machines: usize) -> bool {
    let Ok(rd) = std::fs::read_dir(root) else { return false };
    rd.flatten().any(|e| {
        let p = e.path();
        p.is_dir() && (0..machines).all(|m| p.join(format!("machine_{m}.bin")).exists())
    })
}

/// One attempt at the kill/restart sequence. Phase 1: run a snapshotting
/// 2-process cluster, SIGKILL the worker as soon as a complete snapshot
/// is on disk, and require the driver to fail with a typed error (exit
/// code 1 — an anyhow error from `Engine::run`, not a panic's 101).
/// Phase 2: relaunch both processes on fresh ports with `--restore` and
/// require the restarted run to reproduce the uninterrupted oracle.
fn try_kill_restart(
    bin: &str,
    dir: &std::path::Path,
    atoms_s: &str,
    oracle: f64,
    extra: &[&str],
) -> Result<(), String> {
    use std::process::{Command, Stdio};
    let snap = dir.join("snaps");
    std::fs::remove_dir_all(&snap).ok();
    std::fs::create_dir_all(&snap).unwrap();
    let snap_s = snap.to_str().unwrap();
    let common = ["--atoms-dir", atoms_s, "--sweeps", "400"];

    // ---- phase 1: snapshot, kill, typed failure ------------------------
    let hosts = dir.join("hosts-kill.txt");
    std::fs::write(&hosts, format!("127.0.0.1:{}\n127.0.0.1:{}\n", free_port(), free_port()))
        .unwrap();
    let hosts_s = hosts.to_str().unwrap();
    let snap_args = ["--snapshot-every", "2000", "--snapshot-dir", snap_s];
    let mut worker = Command::new(bin)
        .args(["worker", "--me", "1", "--hosts", hosts_s])
        .args(common)
        .args(extra)
        .args(snap_args)
        .env("GRAPHLAB_PEER_GRACE_SECS", "2")
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn graphlab worker");
    let mut driver = Command::new(bin)
        .args(["run", "pagerank", "--cluster", hosts_s])
        .args(common)
        .args(extra)
        .args(snap_args)
        .env("GRAPHLAB_PEER_GRACE_SECS", "2")
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn graphlab run --cluster");

    // Wait for the first complete cut, then SIGKILL the worker mid-run.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        if has_complete_snapshot(&snap, 2) {
            break;
        }
        if let Some(st) = driver.try_wait().expect("poll driver") {
            worker.kill().ok();
            worker.wait().ok();
            let out = driver.wait_with_output().expect("collect driver output");
            return Err(format!(
                "driver exited ({st}) before any complete snapshot:\n{}\n{}",
                String::from_utf8_lossy(&out.stdout),
                String::from_utf8_lossy(&out.stderr)
            ));
        }
        if std::time::Instant::now() > deadline {
            worker.kill().ok();
            worker.wait().ok();
            driver.kill().ok();
            driver.wait().ok();
            return Err("no complete snapshot appeared within 60s".into());
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    worker.kill().expect("SIGKILL worker");
    worker.wait().expect("reap worker");

    // The driver must notice the dead peer and fail with a typed error.
    let dstatus = wait_with_deadline(&mut driver, 120, "driver (peer killed)");
    let dout = driver.wait_with_output().expect("collect driver output");
    let dstdout = String::from_utf8_lossy(&dout.stdout).to_string();
    let dstderr = String::from_utf8_lossy(&dout.stderr).to_string();
    if dstatus.success() {
        return Err(format!(
            "driver succeeded despite the killed worker:\n{dstdout}"
        ));
    }
    assert_eq!(
        dstatus.code(),
        Some(1),
        "driver must fail with a typed error (exit 1), not a panic:\n{dstdout}\n{dstderr}"
    );

    // ---- phase 2: restart both processes from the snapshot -------------
    let hosts2 = dir.join("hosts-restart.txt");
    std::fs::write(&hosts2, format!("127.0.0.1:{}\n127.0.0.1:{}\n", free_port(), free_port()))
        .unwrap();
    let hosts2_s = hosts2.to_str().unwrap();
    let mut worker2 = Command::new(bin)
        .args(["worker", "--me", "1", "--hosts", hosts2_s])
        .args(common)
        .args(extra)
        .args(["--restore", snap_s])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn restarted worker");
    let rout = Command::new(bin)
        .args(["run", "pagerank", "--cluster", hosts2_s])
        .args(common)
        .args(extra)
        .args(["--restore", snap_s])
        .output()
        .expect("spawn restarted driver");
    let rstdout = String::from_utf8_lossy(&rout.stdout).to_string();
    let rstderr = String::from_utf8_lossy(&rout.stderr).to_string();
    if !rout.status.success() {
        worker2.kill().ok();
        worker2.wait().ok();
        return Err(format!("restarted driver failed:\n{rstdout}\n{rstderr}"));
    }
    // Recovery correctness: the restarted run converges to the
    // uninterrupted run's fixed point (sum-of-ranks probe; the restored
    // trajectory differs, so the tolerance is looser than the
    // deterministic-equality check in the plain smoke).
    let probe = parse_probe(&rstdout)
        .unwrap_or_else(|| panic!("restarted driver printed no probe line:\n{rstdout}"));
    assert!(
        (probe - oracle).abs() < 0.05,
        "restored run diverged from uninterrupted oracle: {probe} vs {oracle}"
    );
    let status = wait_with_deadline(&mut worker2, 120, "restarted worker");
    assert!(status.success(), "restarted worker exited with {status}");
    Ok(())
}

/// The paper's fault-tolerance claim (Sec. 4.3) as real processes: a
/// 2-process cluster snapshots to disk, one worker is SIGKILLed mid-run,
/// the driver fails with a typed error, and a restarted cluster with
/// `--restore` reproduces the uninterrupted result. Retried on fresh
/// ports like the plain smoke.
#[test]
#[ignore = "spawns and kills real graphlab processes; run with --ignored (CI fault-smoke)"]
fn multi_process_kill_restart_from_snapshot() {
    let bin = env!("CARGO_BIN_EXE_graphlab");
    let dir = std::env::temp_dir().join(format!("graphlab-fault-smoke-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (atoms_s, oracle) = prepare_store_and_oracle(bin, &dir, &["--sweeps", "400"]);

    let mut last_err = String::new();
    for attempt in 0..3 {
        match try_kill_restart(bin, &dir, &atoms_s, oracle, &[]) {
            Ok(()) => {
                std::fs::remove_dir_all(&dir).ok();
                return;
            }
            Err(e) => {
                eprintln!("kill/restart attempt {attempt} failed, retrying on fresh ports: {e}");
                last_err = e;
            }
        }
    }
    panic!("kill/restart smoke failed on 3 attempts; last error:\n{last_err}");
}

/// The same kill/restart sequence with the locking engine running a
/// 4-thread executor pool per machine. In-flight transactions at the
/// marker release locks via post-marker channel messages, so the
/// Chandy-Lamport cut stays consistent regardless of pool threading;
/// this exercises that argument with a real SIGKILL. `--eps 1e-8`
/// keeps the run alive long enough to commit a snapshot before the
/// kill.
#[test]
#[ignore = "spawns and kills real graphlab processes; run with --ignored (CI fault-smoke)"]
fn multi_process_kill_restart_locking_threads4() {
    let bin = env!("CARGO_BIN_EXE_graphlab");
    let extra = ["--engine", "locking", "--threads", "4", "--eps", "1e-8"];
    let dir =
        std::env::temp_dir().join(format!("graphlab-fault-smoke-lock-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut oracle_extra = vec!["--sweeps", "400"];
    oracle_extra.extend_from_slice(&extra);
    let (atoms_s, oracle) = prepare_store_and_oracle(bin, &dir, &oracle_extra);

    let mut last_err = String::new();
    for attempt in 0..3 {
        match try_kill_restart(bin, &dir, &atoms_s, oracle, &extra) {
            Ok(()) => {
                std::fs::remove_dir_all(&dir).ok();
                return;
            }
            Err(e) => {
                eprintln!(
                    "locking kill/restart attempt {attempt} failed, retrying on fresh ports: {e}"
                );
                last_err = e;
            }
        }
    }
    panic!("locking kill/restart smoke failed on 3 attempts; last error:\n{last_err}");
}
