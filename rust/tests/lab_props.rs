//! Experiment-lab properties (PR 7): the collector → executor →
//! ingestor → storage pipeline holds together end to end.
//!
//! * The `lab-metric` line `ExecStats` emits round-trips through the
//!   ingestor — the emitter and parser can never drift silently.
//! * Run-output fixtures (real shape, truncated, garbage) parse into
//!   typed records or typed errors — never panics.
//! * The quick preset expands to the acceptance matrix (≥ 8 cells,
//!   ≥ 2 engines × ≥ 2 transports × 2 scales).
//! * An in-process `lab --quick` sweep appends well-formed rows to a
//!   fresh run database, and `report` computes per-cell medians and
//!   direction-aware baseline deltas from them.
//! * The `#[ignore]`d smoke runs the real child-process executor
//!   through the `graphlab` binary (CI's bench-smoke job runs the same
//!   path via `graphlab lab --quick --preset all`).

use std::path::PathBuf;

use graphlab::engine::ExecStats;
use graphlab::lab::config::{CellKind, SweepConfig};
use graphlab::lab::exec::{run_sweep, ExecOpts};
use graphlab::lab::ingest::{self, IngestError, MetricValue};
use graphlab::lab::report;
use graphlab::lab::store::{Outcome, RunDb};

fn temp_db(tag: &str) -> RunDb {
    let dir = std::env::temp_dir().join(format!("graphlab-lab-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("runs.jsonl");
    let _ = std::fs::remove_file(&path);
    RunDb::at(path)
}

#[test]
fn exec_stats_line_round_trips_through_ingestor() {
    let stats = ExecStats {
        updates: 24_000,
        sweeps: 6,
        seconds: 1.25,
        updates_per_machine: vec![12_100, 11_900],
        bytes_sent: vec![40_960, 40_000],
        msgs_sent: vec![96, 94],
    };
    let output = format!("{}\nbytes sent per machine: {:?}\n", stats.lab_metric_line(), stats.bytes_sent);
    let parsed = ingest::parse_run_output(&output).expect("emitter output must ingest");
    assert_eq!(parsed.num("updates"), Some(24_000.0));
    assert_eq!(parsed.num("sweeps"), Some(6.0));
    assert_eq!(parsed.num("machines"), Some(2.0));
    assert_eq!(parsed.num("bytes_sent"), Some(80_960.0));
    assert!((parsed.num("updates_per_sec").unwrap() - stats.updates_per_sec()).abs() < 0.1);
    assert!((parsed.num("balance").unwrap() - stats.balance()).abs() < 1e-3);
    assert_eq!(
        parsed.metric("bytes_per_machine"),
        Some(&MetricValue::List(vec![40_960.0, 40_000.0]))
    );
    assert_eq!(parsed.bytes_per_machine, Some(vec![40_960, 40_000]));
}

#[test]
fn truncated_and_garbage_output_are_typed_errors() {
    // Child killed mid-write: dangling token on the metric line.
    let torn = "lab-metric updates=100 seconds=0.5 updates_per";
    assert!(matches!(
        ingest::parse_run_output(torn),
        Err(IngestError::BadPair { .. })
    ));
    // Run died before reporting: probe chatter only.
    let silent = "partitioned 1000 vertices\nprobe total_rank=1.0\n";
    assert!(matches!(ingest::parse_run_output(silent), Err(IngestError::NoMetrics)));
    // Binary garbage: typed error, not a panic.
    let garbage = "\u{0}\u{1}\u{FFFD}ühh\n\u{7f}\u{7f}\u{7f}";
    assert!(ingest::parse_run_output(garbage).is_err());
}

#[test]
fn quick_preset_is_the_acceptance_matrix() {
    let cfg = SweepConfig::preset("quick", true).unwrap();
    let cells = cfg.expand();
    assert!(cells.len() >= 8, "quick preset must be >= 8 cells, got {}", cells.len());
    let count = |f: &dyn Fn(&graphlab::lab::Cell) -> String| {
        let mut vals: Vec<String> = cells.iter().map(f).collect();
        vals.sort();
        vals.dedup();
        vals.len()
    };
    assert!(count(&|c| c.engine.clone()) >= 2, "needs >= 2 engines");
    assert!(count(&|c| c.transport.clone()) >= 2, "needs >= 2 transports");
    assert!(count(&|c| c.scale.to_string()) >= 2, "needs 2 scales");
    // Every preset must expand to a non-empty, duplicate-free matrix.
    for name in graphlab::lab::config::PRESETS {
        let quick = SweepConfig::preset(name, true).unwrap().expand();
        let full = SweepConfig::preset(name, false).unwrap().expand();
        assert!(!quick.is_empty() && !full.is_empty(), "preset {name} expands to nothing");
        let mut ids: Vec<String> = full.iter().map(|c| c.id()).collect();
        let before = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(before, ids.len(), "preset {name} has duplicate cells");
    }
}

#[test]
fn cell_argv_is_executable_shape() {
    let cfg = SweepConfig::preset("fig8b", false).unwrap();
    for cell in cfg.expand() {
        let argv = cell.argv();
        assert_eq!(argv[0], "run");
        assert!(argv.contains(&"--latency-us".to_string()), "fig8b injects latency");
        assert!(argv.contains(&"--maxpending".to_string()));
    }
    let micros = SweepConfig::preset("wire", true).unwrap().expand();
    assert!(micros.iter().all(|c| c.kind == CellKind::Micro));
    assert_eq!(micros[0].argv()[0], "lab");
}

/// The tentpole e2e at test scale: a real (in-process) sweep over a
/// shrunk 8-cell matrix writes well-formed rows, and the report computes
/// medians and baseline deltas from them.
#[test]
fn inproc_sweep_fills_the_run_database() {
    let sweep = SweepConfig::from_json_text(
        r#"{
            "name": "test-quick",
            "apps": ["pagerank"],
            "engines": ["chromatic", "locking"],
            "transports": ["inproc", "tcp"],
            "machines": [2],
            "scales": [300, 600],
            "sweeps": 2,
            "eps": 0,
            "timeout_secs": 120
        }"#,
        false,
    )
    .unwrap();
    let cells = sweep.expand();
    assert_eq!(cells.len(), 8);
    let db = temp_db("inproc");
    let opts = ExecOpts { db: db.clone(), bin: None, inproc: true, echo: false };
    let summary = run_sweep(&sweep, &opts).expect("sweep must produce at least one ok run");
    assert_eq!(summary.runs, 8);
    assert_eq!(summary.ok, 8, "all in-proc quick cells should succeed");

    let (records, issues) = db.load().unwrap();
    assert!(issues.is_empty(), "fresh database must be clean: {issues:?}");
    assert_eq!(records.len(), 8);
    for rec in &records {
        assert_eq!(rec.schema, 1);
        assert_eq!(rec.config, "test-quick");
        assert_eq!(rec.outcome, Outcome::Ok);
        assert!(rec.num("updates").unwrap() > 0.0);
        assert!(rec.num("updates_per_sec").unwrap() > 0.0);
        assert!(rec.bytes_per_machine.is_some(), "distributed runs report bytes");
        assert!(
            rec.probes.iter().any(|(k, _)| k == "total_rank"),
            "pagerank rows carry the convergence probe"
        );
    }
    // Distinct cells, and determinism across the two scales is visible
    // in the ids.
    let mut ids: Vec<&str> = records.iter().map(|r| r.cell.as_str()).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 8);

    // Report: medians per cell, direction-aware delta vs a baseline that
    // is simply an earlier copy of the same rows (delta ~ 0, no
    // regression flags).
    let text = report::render(&records, Some(&records));
    for id in &ids {
        assert!(text.contains(*id), "report must list {id}");
    }
    assert!(text.contains("updates_per_sec"));
    assert!(!text.contains("REGRESSION"), "identical baseline cannot regress:\n{text}");

    std::fs::remove_dir_all(db.path.parent().unwrap()).ok();
}

/// Micro cells flow through the same pipeline.
#[test]
fn inproc_micro_cells_ingest() {
    let sweep = SweepConfig::from_json_text(
        r#"{"name": "test-micro", "micros": ["wire-codec"], "scales": [640]}"#,
        false,
    )
    .unwrap();
    let db = temp_db("micro");
    let opts = ExecOpts { db: db.clone(), bin: None, inproc: true, echo: false };
    let summary = run_sweep(&sweep, &opts).unwrap();
    assert_eq!(summary.ok, 1);
    let (records, _) = db.load().unwrap();
    assert_eq!(records[0].kind, "micro");
    assert!(records[0].num("mb_per_sec").unwrap() > 0.0);
    std::fs::remove_dir_all(db.path.parent().unwrap()).ok();
}

/// Real child-process supervision through the installed binary — the
/// same path CI's bench-smoke exercises via `graphlab lab --quick`.
#[test]
#[ignore = "spawns real graphlab child processes; run with --ignored (CI bench-smoke)"]
fn lab_quick_child_smoke() {
    let bin = env!("CARGO_BIN_EXE_graphlab");
    let dir = std::env::temp_dir().join(format!("graphlab-lab-child-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let db_path: PathBuf = dir.join("runs.jsonl");
    let out = std::process::Command::new(bin)
        .args(["lab", "--quick", "--db"])
        .arg(&db_path)
        .output()
        .expect("spawning graphlab lab");
    assert!(
        out.status.success(),
        "lab --quick failed:\n{}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let (records, issues) = RunDb::at(&db_path).load().unwrap();
    assert!(issues.is_empty(), "{issues:?}");
    assert!(records.len() >= 8, "quick matrix is >= 8 cells, got {}", records.len());
    assert!(records.iter().all(|r| r.outcome == Outcome::Ok));
    // ... and `lab report` renders from the same database.
    let rep = std::process::Command::new(bin)
        .args(["lab", "report", "--db"])
        .arg(&db_path)
        .output()
        .expect("spawning graphlab lab report");
    assert!(rep.status.success());
    let text = String::from_utf8_lossy(&rep.stdout);
    assert!(text.contains("updates_per_sec"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}
