//! Property tests for the wire codec (seed-swept, in-repo generators —
//! no proptest crate offline): every public `Wire` impl round-trips over
//! random values, and decoding is **total** — every strict prefix of a
//! valid encoding is an error, never a panic.

use graphlab::apps::{als, coseg, gibbs, ner, pagerank};
use graphlab::distributed::locks::TxnId;
use graphlab::distributed::termination::Token;
use graphlab::scheduler::Task;
use graphlab::util::Rng;
use graphlab::wire::{self, Wire};

/// Round-trip plus prefix-totality: decoding any strict prefix of the
/// encoding must return an error (no panic, no silent success).
fn assert_codec<W: Wire + PartialEq + std::fmt::Debug>(v: &W) {
    let bytes = wire::to_bytes(v);
    let back: W = wire::from_bytes(&bytes).unwrap();
    assert_eq!(&back, v);
    for cut in 0..bytes.len() {
        assert!(
            wire::from_bytes::<W>(&bytes[..cut]).is_err(),
            "{cut}-byte prefix of a {}-byte encoding decoded",
            bytes.len()
        );
    }
}

fn f32s(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal()).collect()
}

#[test]
fn prop_pagerank_types_round_trip() {
    let mut rng = Rng::new(1);
    for _ in 0..50 {
        assert_codec(&pagerank::PrVertex { rank: rng.f32() });
        assert_codec(&pagerank::PrEdge {
            to_lo: rng.normal(),
            to_hi: rng.normal(),
        });
    }
}

#[test]
fn prop_als_types_round_trip() {
    let mut rng = Rng::new(2);
    for _ in 0..50 {
        let d = rng.gen_range(40);
        assert_codec(&als::AlsVertex {
            factor: f32s(&mut rng, d),
            sse: rng.f32(),
            cnt: rng.gen_range(100) as f32,
            is_user: rng.chance(0.5),
        });
        assert_codec(&als::AlsEdge {
            rating: rng.uniform(1.0, 5.0),
        });
    }
}

#[test]
fn prop_coseg_types_round_trip() {
    let mut rng = Rng::new(3);
    for _ in 0..50 {
        let l = 1 + rng.gen_range(8);
        assert_codec(&coseg::CosegVertex {
            belief: f32s(&mut rng, l),
            npot: f32s(&mut rng, l),
            appearance: f32s(&mut rng, l),
            truth: rng.gen_range(256) as u8,
        });
        assert_codec(&coseg::CosegEdge {
            msg_to_lo: f32s(&mut rng, l),
            msg_to_hi: f32s(&mut rng, l),
            lam: rng.f32(),
        });
    }
}

#[test]
fn prop_ner_types_round_trip() {
    let mut rng = Rng::new(4);
    for _ in 0..50 {
        let k = 1 + rng.gen_range(12);
        assert_codec(&ner::NerVertex {
            dist: f32s(&mut rng, k),
            is_np: rng.chance(0.5),
            seed: rng.chance(0.3).then(|| rng.gen_range(k) as u8),
            truth: rng.chance(0.5).then(|| rng.gen_range(k) as u8),
        });
        assert_codec(&ner::NerEdge { count: rng.f32() });
    }
}

#[test]
fn prop_gibbs_vertex_round_trips() {
    let mut rng = Rng::new(5);
    for _ in 0..50 {
        assert_codec(&gibbs::GibbsVertex {
            spin: rng.gen_range(2) as u8,
            field: rng.normal(),
            ones: rng.next_u64(),
            samples: rng.next_u64(),
        });
    }
}

#[test]
fn prop_protocol_types_round_trip() {
    let mut rng = Rng::new(6);
    for _ in 0..50 {
        assert_codec(&Task {
            vertex: rng.next_u64() as u32,
            priority: rng.f64(),
        });
        assert_codec(&Token {
            count: rng.next_u64() as i64 >> 8,
            black: rng.chance(0.5),
            round: rng.next_u64(),
        });
        assert_codec(&TxnId {
            machine: rng.gen_range(64),
            seq: rng.next_u64(),
        });
    }
}

#[test]
fn prop_nested_frames_round_trip() {
    // The chromatic ghost flush and locking release shapes, built from
    // containers (the Msg enums themselves are engine-internal; their
    // grammar is these same container combinators plus a tag byte).
    let mut rng = Rng::new(7);
    for _ in 0..25 {
        let verts: Vec<(u32, u64, als::AlsVertex)> = (0..rng.gen_range(12))
            .map(|i| {
                (i as u32, rng.next_u64(), als::AlsVertex {
                    factor: f32s(&mut rng, 5),
                    sse: rng.f32(),
                    cnt: 1.0,
                    is_user: true,
                })
            })
            .collect();
        let tasks: Vec<Task> = (0..rng.gen_range(8))
            .map(|_| Task {
                vertex: rng.gen_range(1000) as u32,
                priority: rng.f64(),
            })
            .collect();
        let values: Vec<(String, Vec<f64>)> = vec![
            ("rmse".to_string(), vec![rng.f64(); rng.gen_range(4)]),
            ("total_rank".to_string(), vec![]),
        ];
        assert_codec(&(verts, tasks, values));
    }
}

#[test]
fn garbage_input_never_panics() {
    // Fuzz-ish: random byte soup must decode to Ok or Err, never panic.
    let mut rng = Rng::new(8);
    for _ in 0..200 {
        let len = rng.gen_range(64);
        let bytes: Vec<u8> = (0..len).map(|_| rng.gen_range(256) as u8).collect();
        let _ = wire::from_bytes::<als::AlsVertex>(&bytes);
        let _ = wire::from_bytes::<ner::NerVertex>(&bytes);
        let _ = wire::from_bytes::<Vec<(u32, u64, pagerank::PrVertex)>>(&bytes);
        let _ = wire::from_bytes::<(String, Vec<f64>)>(&bytes);
        let _ = wire::from_bytes::<Option<Token>>(&bytes);
    }
}
