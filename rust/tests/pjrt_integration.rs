//! PJRT-path integration: the AOT Pallas kernels driving full distributed
//! runs must agree with the native math (all tests no-op gracefully when
//! `make artifacts` has not been run).

use graphlab::apps::{self, als, coseg, ner};
use graphlab::engine::{Engine, EngineKind};
use graphlab::partition::{Coloring, Partition};
use graphlab::scheduler::{Policy, SchedSpec};

fn artifacts() -> bool {
    if graphlab::runtime::available() {
        true
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        false
    }
}

#[test]
fn als_pjrt_equals_native_distributed() {
    if !artifacts() {
        return;
    }
    let data = graphlab::datagen::netflix(300, 150, 20, 5, 0.1, 7);
    let rmse = |use_pjrt: bool| {
        let g = als::build(&data, 10, 1);
        let n = g.num_vertices();
        let coloring = Coloring::bipartite(&g).unwrap();
        let partition = Partition::random(n, 3, 3);
        let prog = als::Als { d: 10, lambda: 0.08, use_pjrt };
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(3)
            .max_sweeps(6)
            .with_coloring(coloring)
            .with_partition(partition)
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
        als::rmse_direct(&exec.graph)
    };
    let (nat, pj) = (rmse(false), rmse(true));
    assert!((nat - pj).abs() < 5e-3, "native={nat} pjrt={pj}");
    assert!(pj < 0.3, "pjrt ALS must converge: {pj}");
}

#[test]
fn coem_pjrt_equals_native_distributed() {
    if !artifacts() {
        return;
    }
    let data = graphlab::datagen::ner(400, 200, 20, 8, 0.15, 9);
    let final_dists = |use_pjrt: bool| {
        let g = ner::build(&data);
        let n = g.num_vertices();
        let coloring = Coloring::bipartite(&g).unwrap();
        let partition = Partition::random(n, 2, 3);
        let prog = ner::Coem { k: 8, smoothing: 0.01, eps: 1e-4, use_pjrt };
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(2)
            .max_sweeps(6)
            .with_coloring(coloring)
            .with_partition(partition)
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
        let g = exec.graph;
        g.vertex_ids().flat_map(|v| g.vertex_data(v).dist.clone()).collect::<Vec<f32>>()
    };
    let nat = final_dists(false);
    let pj = final_dists(true);
    let max_diff = nat.iter().zip(&pj).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "max diff {max_diff}");
}

#[test]
fn lbp_pjrt_runs_in_locking_engine() {
    if !artifacts() {
        return;
    }
    let data = graphlab::datagen::video(3, 8, 10, 5, 0.4, 3);
    let g = coseg::build(&data, 0.8);
    let n = g.num_vertices();
    let partition = Partition::blocked(n, 2);
    let prog = coseg::Coseg { labels: 5, eps: 5e-3, sigma2: 0.5, use_pjrt: true };
    let exec = Engine::new(EngineKind::Locking)
        .machines(2)
        .maxpending(64)
        .scheduler(SchedSpec::ws(Policy::Priority, 1))
        .max_updates(n as u64 * 20)
        .with_partition(partition)
        .run(g, &prog, apps::all_vertices(n))
        .unwrap();
    let (g, stats) = (exec.graph, exec.stats);
    assert!(stats.updates >= n as u64 / 2);
    // Beliefs are normalized distributions.
    for v in g.vertex_ids() {
        let s: f32 = g.vertex_data(v).belief.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "belief sum {s} at v{v}");
    }
}
