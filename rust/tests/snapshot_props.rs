//! Snapshot/recovery properties (paper Sec. 4.3): any Chandy–Lamport cut
//! the engines take is consistent — a run restarted from it converges to
//! the uninterrupted run's fixed point; torn or truncated snapshot
//! directories are typed errors (and skipped by discovery), never panics;
//! and a deterministic `FaultPlan` kill at frame `k`, swept across the
//! message schedule, round-trips through `restore_from` on both
//! distributed engines.

use std::path::PathBuf;

use graphlab::apps::{self, pagerank};
use graphlab::distributed::{snapshot, FaultPlan, SnapshotTrigger};
use graphlab::engine::{Engine, EngineKind};

mod common;
use common::assert_ranks_close;

/// Fresh per-test scratch directory under the system temp dir.
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "graphlab-snapprops-{name}-{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run PageRank on `kind` with extra builder knobs applied by `cfg`
/// (snapshot/restore/fault), returning the final ranks.
fn run_pr(
    kind: EngineKind,
    machines: usize,
    n: usize,
    edges: &[(u32, u32)],
    cfg: impl FnOnce(Engine<pagerank::PrVertex>) -> Engine<pagerank::PrVertex>,
) -> anyhow::Result<Vec<f32>> {
    let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-7, n, use_pjrt: false };
    let g = pagerank::build(n, edges, 0.15);
    let b = Engine::new(kind)
        .machines(machines)
        .maxpending(64)
        .max_updates(2_000_000)
        .max_sweeps(300)
        .seed(7);
    let exec = cfg(b).run(g, &prog, apps::all_vertices(n))?;
    let g = exec.graph;
    Ok(g.vertex_ids().map(|v| g.vertex_data(v).rank).collect())
}

#[test]
fn snapshot_cuts_are_consistent_across_engines_seeds_and_machine_counts() {
    for kind in [EngineKind::Chromatic, EngineKind::Locking] {
        for machines in [2usize, 3] {
            for seed in [11u64, 23] {
                let n = 240;
                let edges = graphlab::datagen::web_graph(n, 5, seed);
                let label = format!("{kind} x{machines} seed={seed}");
                let oracle = run_pr(kind, machines, n, &edges, |b| b).unwrap();
                // Snapshotting must not perturb the computation.
                let root = tmp(&format!("cut-{kind}-{machines}-{seed}"));
                let with_snap = run_pr(kind, machines, n, &edges, |b| {
                    b.snapshot_every(SnapshotTrigger::Updates(100)).snapshot_to(&root)
                })
                .unwrap();
                assert_ranks_close(&format!("{label} with-snapshots"), &oracle, &with_snap, 1e-4);
                // At least one complete cut committed, covering every machine.
                let snap = snapshot::latest_complete::<pagerank::PrVertex, pagerank::PrEdge>(&root)
                    .unwrap()
                    .unwrap_or_else(|| panic!("{label}: no complete snapshot on disk"));
                assert_eq!(snap.machines, machines, "{label}");
                assert!(!snap.verts.is_empty(), "{label}: empty cut");
                // The cut is consistent: a run restarted from it reaches the
                // uninterrupted fixed point.
                let restored =
                    run_pr(kind, machines, n, &edges, |b| b.restore_from(&root)).unwrap();
                assert_ranks_close(&format!("{label} restored"), &oracle, &restored, 1e-4);
                std::fs::remove_dir_all(&root).ok();
            }
        }
    }
}

#[test]
fn torn_snapshot_dirs_are_typed_errors_and_skipped_on_restore() {
    let n = 160;
    let edges = graphlab::datagen::web_graph(n, 5, 3);
    let root = tmp("torn");
    let oracle = run_pr(EngineKind::Chromatic, 2, n, &edges, |b| b).unwrap();
    run_pr(EngineKind::Chromatic, 2, n, &edges, |b| {
        b.snapshot_every(SnapshotTrigger::Updates(50)).snapshot_to(&root)
    })
    .unwrap();
    // Truncate one machine part of the newest complete epoch: loading that
    // epoch becomes a typed error (not a panic, not garbage data).
    let newest = snapshot::latest_complete::<pagerank::PrVertex, pagerank::PrEdge>(&root)
        .unwrap()
        .expect("run committed no snapshot");
    let victim = root.join(format!("snapshot_{}", newest.epoch));
    let part = victim.join("machine_0.bin");
    let bytes = std::fs::read(&part).unwrap();
    std::fs::write(&part, &bytes[..bytes.len() / 2]).unwrap();
    let err = snapshot::load::<pagerank::PrVertex, pagerank::PrEdge>(&victim).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("snapshot") || msg.contains("truncat"),
        "undiagnostic torn-snapshot error: {msg}"
    );
    // Discovery skips the torn epoch; restore still succeeds (from an
    // older complete cut) and reaches the oracle fixed point.
    let restored = run_pr(EngineKind::Chromatic, 2, n, &edges, |b| b.restore_from(&root)).unwrap();
    assert_ranks_close("torn-restore", &oracle, &restored, 1e-4);
    // Corrupt every epoch: nothing is restorable, and the engine treats
    // that as "no snapshot" — a clean from-scratch run, never a panic.
    for entry in std::fs::read_dir(&root).unwrap().flatten() {
        let d = entry.path();
        if !d.is_dir() {
            continue;
        }
        for f in ["machine_0.bin", "machine_1.bin"] {
            let p = d.join(f);
            if p.exists() {
                std::fs::write(&p, b"garbage").unwrap();
            }
        }
    }
    assert!(snapshot::latest_complete::<pagerank::PrVertex, pagerank::PrEdge>(&root)
        .unwrap()
        .is_none());
    let scratch = run_pr(EngineKind::Chromatic, 2, n, &edges, |b| b.restore_from(&root)).unwrap();
    assert_ranks_close("all-torn-restore", &oracle, &scratch, 1e-4);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn kill_at_frame_k_round_trips_through_restore_on_both_engines() {
    // Short grace so killed runs abort in ~1s instead of the 30s default.
    // Only fault-injected runs experience peer failures, so this is safe
    // process-wide.
    std::env::set_var("GRAPHLAB_PEER_GRACE_SECS", "1");
    let n = 200;
    let edges = graphlab::datagen::web_graph(n, 5, 9);
    for kind in [EngineKind::Chromatic, EngineKind::Locking] {
        let oracle = run_pr(kind, 2, n, &edges, |b| b).unwrap();
        // k sweeps the message schedule: kill before the first frame, in
        // the thick of the run, and far beyond the schedule (never fires).
        for k in [0u64, 1, 3, 10, 60, 1_000_000] {
            let label = format!("{kind} kill@{k}");
            let root = tmp(&format!("kill-{kind}-{k}"));
            let res = run_pr(kind, 2, n, &edges, |b| {
                b.snapshot_every(SnapshotTrigger::Updates(80))
                    .snapshot_to(&root)
                    .fault_plan(FaultPlan::kill_at(1, k))
            });
            if k >= 1_000_000 {
                // Beyond the schedule: the plan never fires, the run is
                // just a snapshotting run.
                assert_ranks_close(&label, &oracle, &res.unwrap(), 1e-4);
            } else {
                // Machine 1 died mid-run: a typed error naming the
                // failure, never a panic.
                let err = res.err().unwrap_or_else(|| {
                    panic!("{label}: run succeeded despite the kill")
                });
                let msg = format!("{err:#}");
                assert!(
                    msg.contains("machine") || msg.contains("peer") || msg.contains("fault"),
                    "{label}: undiagnostic failure: {msg}"
                );
            }
            // Recovery: restart from whatever complete snapshot the dead
            // run left (possibly none, if the kill preceded the first
            // commit — then this is a from-scratch run). Either way the
            // restarted run reproduces the uninterrupted fixed point.
            let restored = run_pr(kind, 2, n, &edges, |b| b.restore_from(&root)).unwrap();
            assert_ranks_close(&format!("{label} restored"), &oracle, &restored, 1e-4);
            std::fs::remove_dir_all(&root).ok();
        }
    }
}
