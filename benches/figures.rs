//! Paper-figure regeneration as a bench target: `cargo bench --bench
//! figures` produces every table/figure CSV under `results/` and prints
//! the headline comparisons (the "rows the paper reports").
//!
//! This is the end-to-end benchmark harness of DESIGN.md §Experiment-index;
//! see EXPERIMENTS.md for paper-vs-measured shape checks.

fn main() {
    let out = std::path::Path::new("results");
    graphlab::sim::figures::run_figure("all", out).expect("figure generation");
    println!("\nall figures written to results/ — see EXPERIMENTS.md");
}
