//! Engine micro-benchmarks (in-repo harness; `cargo bench --bench engine`).
//!
//! Covers the §Perf hot paths: scheduler ops, scope assembly + native
//! update execution per engine, ghost-sync volume, lock-table throughput,
//! and the PJRT batched kernel path when artifacts are built.

use graphlab::apps::{self, als, pagerank};
use graphlab::bench::{bench, bench_throughput};
use graphlab::distributed::locks::{LockReq, LockTable, TxnId};
use graphlab::engine::{Engine, EngineKind};
use graphlab::partition::{Coloring, Partition};
use graphlab::scheduler::{FifoScheduler, Policy, PriorityScheduler, SchedSpec, Scheduler, Task, WorkStealing};

fn bench_schedulers() {
    let n = 100_000;
    bench_throughput("scheduler/fifo push+pop", 0.4, n, || {
        let mut s = FifoScheduler::new(n);
        for v in 0..n as u32 {
            s.push(Task { vertex: v, priority: 0.0 });
        }
        while s.pop().is_some() {}
    });
    bench_throughput("scheduler/priority push+pop", 0.4, n, || {
        let mut s = PriorityScheduler::new(n);
        for v in 0..n as u32 {
            s.push(Task { vertex: v, priority: (v % 97) as f64 });
        }
        while s.pop().is_some() {}
    });
}

fn bench_work_stealing() {
    // Contended push/pop: 4 threads, disjoint vertex ranges, local pushes
    // + drain with steals — the shared engine's hot path shape.
    let n = 100_000;
    let workers = 4usize;
    bench_throughput("scheduler/work-stealing 4t push+pop", 0.4, n, || {
        let ws = WorkStealing::new(Policy::Fifo, n, workers, 1);
        std::thread::scope(|s| {
            for w in 0..workers {
                let ws = &ws;
                s.spawn(move || {
                    let mut rng = graphlab::util::Rng::new(w as u64);
                    let per = (n / workers) as u32;
                    let lo = w as u32 * per;
                    for v in lo..lo + per {
                        ws.push(w, Task { vertex: v, priority: 0.0 });
                    }
                    loop {
                        match ws.pop(w, &mut rng) {
                            Some(_) => ws.task_done(),
                            None => {
                                if ws.outstanding() == 0 {
                                    break;
                                }
                                std::hint::spin_loop();
                            }
                        }
                    }
                });
            }
        });
    });
    // The single-mutex baseline under identical contention, for the gap.
    bench_throughput("scheduler/global-mutex 4t push+pop", 0.4, n, || {
        let sched = std::sync::Mutex::new(FifoScheduler::new(n));
        std::thread::scope(|s| {
            for w in 0..workers {
                let sched = &sched;
                s.spawn(move || {
                    let per = (n / workers) as u32;
                    let lo = w as u32 * per;
                    for v in lo..lo + per {
                        sched.lock().unwrap().push(Task { vertex: v, priority: 0.0 });
                    }
                    while sched.lock().unwrap().pop().is_some() {}
                });
            }
        });
    });
}

fn bench_shared_engine_thread_sweep() {
    // The BENCH_pr2 shape, abbreviated: PageRank with eps=0 (always
    // reschedules) capped at 2 sweeps' worth of updates, old vs new
    // scheduler at 4 threads. The full 1/2/4/8 sweep with JSON output is
    // `graphlab bench-sched`.
    let n = 20_000;
    let edges = graphlab::datagen::web_graph(n, 8, 1);
    let prog = pagerank::PageRank { alpha: 0.15, eps: 0.0, n, use_pjrt: false };
    for spec in [SchedSpec::global(Policy::Fifo, 1), SchedSpec::ws(Policy::Fifo, 1)] {
        let name = format!("pagerank/shared 4w 2-sweeps {}", spec.name());
        bench_throughput(&name, 1.0, 2 * n, || {
            let g = pagerank::build(n, &edges, 0.15);
            let exec = Engine::new(EngineKind::Shared)
                .workers(4)
                .scheduler(spec)
                .max_updates(2 * n as u64)
                .run(g, &prog, apps::all_vertices(n))
                .unwrap();
            assert!(exec.stats.updates >= n as u64);
        });
    }
}

fn bench_lock_table() {
    let n = 50_000usize;
    bench_throughput("locks/grant+release cycle", 0.4, n, || {
        let mut lt = LockTable::new();
        for i in 0..n as u32 {
            let t = TxnId { machine: 0, seq: i as u64 };
            assert!(lt.request(LockReq { txn: t, vertex: i % 1024, write: false }));
            lt.release(i % 1024, t, false);
        }
    });
}

fn bench_pagerank_engines() {
    let n = 20_000;
    let edges = graphlab::datagen::web_graph(n, 8, 1);
    let prog = pagerank::PageRank { alpha: 0.15, eps: f32::INFINITY, n, use_pjrt: false };

    bench_throughput("pagerank/shared 4w one-sweep", 1.0, n, || {
        let g = pagerank::build(n, &edges, 0.15);
        let exec = Engine::new(EngineKind::Shared)
            .workers(4)
            .scheduler(SchedSpec::ws(Policy::Fifo, 1))
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
        assert_eq!(exec.stats.updates, n as u64);
    });

    let coloring_g = pagerank::build(n, &edges, 0.15);
    let coloring = Coloring::greedy(&coloring_g);
    let partition = Partition::random(n, 4, 3);
    bench_throughput("pagerank/chromatic 4m one-sweep", 1.5, n, || {
        let g = pagerank::build(n, &edges, 0.15);
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(4)
            .max_sweeps(1)
            .with_coloring(coloring.clone())
            .with_partition(partition.clone())
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
        assert_eq!(exec.stats.updates, n as u64);
    });

    bench_throughput("pagerank/locking 4m one-sweep", 2.0, n, || {
        let g = pagerank::build(n, &edges, 0.15);
        // Per-machine cap n/4 + 1000: the builder splits the total.
        let _exec = Engine::new(EngineKind::Locking)
            .machines(4)
            .maxpending(256)
            .scheduler(SchedSpec::ws(Policy::Fifo, 1))
            .max_updates(n as u64 + 4000)
            .with_partition(partition.clone())
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
    });
}

fn bench_als_paths() {
    let data = graphlab::datagen::netflix(800, 400, 25, 8, 0.2, 5);
    let coloring_g = als::build(&data, 20, 1);
    let n = coloring_g.num_vertices();
    let coloring = Coloring::bipartite(&coloring_g).unwrap();
    let partition = Partition::random(n, 2, 3);

    let one_sweep = |use_pjrt: bool| {
        let g = als::build(&data, 20, 1);
        let prog = als::Als { d: 20, lambda: 0.08, use_pjrt };
        let _exec = Engine::new(EngineKind::Chromatic)
            .machines(2)
            .max_sweeps(1)
            .with_coloring(coloring.clone())
            .with_partition(partition.clone())
            .run(g, &prog, apps::all_vertices(n))
            .unwrap();
    };
    bench_throughput("als/native d=20 one-sweep", 1.5, n, || one_sweep(false));

    if graphlab::runtime::available() {
        // Warm the per-thread executable caches outside the timing loop.
        one_sweep(true);
        bench_throughput("als/pjrt d=20 one-sweep", 1.5, n, || one_sweep(true));
    } else {
        println!("als/pjrt: skipped (run `make artifacts`)");
    }
}

fn main() {
    println!("== engine micro-benchmarks ==");
    bench_schedulers();
    bench_work_stealing();
    bench_shared_engine_thread_sweep();
    bench_lock_table();
    bench_pagerank_engines();
    bench_als_paths();
    bench("partition/two-phase 20k-vertex graph", 1.0, || {
        let edges = graphlab::datagen::web_graph(20_000, 8, 1);
        let g = pagerank::build(20_000, &edges, 0.15);
        let p = graphlab::partition::atoms::two_phase(&g, 64, 8, 2);
        std::hint::black_box(p.edge_cut(&g));
    });
}
