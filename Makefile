# graphlab-rs build orchestration. Tier-1 is plain `cargo build --release
# && cargo test -q`; this Makefile only adds convenience wrappers and the
# `artifacts` AOT-lowering step (the one target that needs Python/JAX).

CARGO ?= cargo
PYTHON ?= python3
ARTIFACTS_DIR := artifacts

.PHONY: all build test check clippy fmt fmt-fix bench lab lab-report figures artifacts clean

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

check: test clippy fmt

clippy:
	$(CARGO) clippy -- -D warnings

fmt:
	$(CARGO) fmt --check

fmt-fix:
	$(CARGO) fmt

bench:
	$(CARGO) bench --bench engine

# The experiment lab (see BENCHMARKS.md): every preset sweep into the
# run database, then the per-cell median / baseline-delta report.
lab:
	$(CARGO) run --release -- lab --preset all

lab-report:
	$(CARGO) run --release -- lab report

figures:
	$(CARGO) bench --bench figures

# AOT-lower the Layer-1 Pallas kernels to HLO text artifacts consumed by
# the Rust runtime (`rust/src/runtime/`). Requires Python with jax; runs
# at build time only — execution never invokes Python.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)
	@echo "artifacts written to $(ARTIFACTS_DIR)/ ($$(ls $(ARTIFACTS_DIR)/*.hlo.txt 2>/dev/null | wc -l) kernels)"

clean:
	$(CARGO) clean
	rm -rf $(ARTIFACTS_DIR) results
