//! Cluster-scale what-if study using the calibrated performance model
//! (DESIGN.md §Substitutions): how the paper's three applications scale
//! from 4 to 64 EC2 nodes, and how GraphLab compares to Hadoop and MPI.
//!
//! ```text
//! cargo run --release --example cluster_sim
//! ```

use graphlab::sim::{self, calibrate, ClusterModel};

fn main() {
    println!("calibrating per-update costs on this machine...");
    let netflix = calibrate::netflix_workload(20);
    let nerw = calibrate::ner_workload();
    let cosegw = calibrate::coseg_workload(1740.0);
    println!("  netflix d=20: {:.1} µs/update", netflix.update_cost * 1e6);
    println!("  ner k=8     : {:.1} µs/update", nerw.update_cost * 1e6);
    println!("  coseg l=5   : {:.1} µs/update", cosegw.update_cost * 1e6);

    println!("\nspeedup (relative to 4 nodes) at paper scale:");
    println!("{:>6} {:>10} {:>10} {:>10}", "nodes", "netflix", "ner", "coseg");
    let deg_net = 2.0 * netflix.num_edges / netflix.num_vertices;
    let deg_ner = 2.0 * nerw.num_edges / nerw.num_vertices;
    let chrom = |nodes: usize, w: &sim::WorkloadModel, deg: f64| {
        sim::chromatic_iter(
            &ClusterModel::ec2_hpc(nodes), w,
            sim::random_cut_fraction(nodes), sim::random_mirrors(nodes, deg),
        ).seconds
    };
    let lockg = |nodes: usize, w: &sim::WorkloadModel| {
        sim::locking_iter(
            &ClusterModel::ec2_hpc(nodes), w,
            sim::grid_cut_fraction(nodes, 1740.0), sim::grid_mirrors(nodes, 1740.0), 100,
        ).seconds
    };
    let base = [chrom(4, &netflix, deg_net), chrom(4, &nerw, deg_ner)];
    let coseg_base = lockg(4, &cosegw);
    for nodes in [4usize, 8, 16, 24, 32, 48, 64] {
        let s_net = base[0] / chrom(nodes, &netflix, deg_net) * 4.0;
        let s_ner = base[1] / chrom(nodes, &nerw, deg_ner) * 4.0;
        let s_cos = coseg_base / lockg(nodes, &cosegw) * 4.0;
        println!("{nodes:>6} {s_net:>10.1} {s_ner:>10.1} {s_cos:>10.1}");
    }

    println!("\none netflix iteration (d=20): graphlab vs hadoop vs mpi:");
    println!("{:>6} {:>12} {:>12} {:>12} {:>8}", "nodes", "graphlab(s)", "hadoop(s)", "mpi(s)", "h/g");
    for nodes in [4usize, 16, 64] {
        let c = ClusterModel::ec2_hpc(nodes);
        let m = sim::random_mirrors(nodes, deg_net);
        let gl = sim::chromatic_iter(&c, &netflix, sim::random_cut_fraction(nodes), m).seconds;
        let hd = sim::hadoop_iter(&c, &netflix).seconds;
        let mp = sim::mpi_iter(&c, &netflix, sim::random_cut_fraction(nodes), m).seconds;
        println!("{nodes:>6} {gl:>12.2} {hd:>12.1} {mp:>12.2} {:>8.0}x", hd / gl);
    }
}
