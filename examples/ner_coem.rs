//! Named Entity Recognition via CoEM (paper Sec. 5.3): synthetic
//! noun-phrase/context co-occurrence graph, chromatic engine by default
//! (`--engine` selects another at runtime).
//!
//! ```text
//! cargo run --release --example ner_coem [-- --nps 8000 --machines 4]
//! ```

use graphlab::apps::{self, ner};
use graphlab::engine::{Engine, EngineKind};
use graphlab::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let nps = args.num_or("nps", 8000usize)?;
    let machines = args.num_or("machines", 4usize)?;
    let engine: EngineKind = args.str_or("engine", "chromatic").parse()?;
    let use_pjrt = graphlab::runtime::available() && !args.flag("no-pjrt");

    let data = graphlab::datagen::ner(nps, nps / 2, 30, 8, 0.1, 5);
    let g = ner::build(&data);
    let n = g.num_vertices();
    println!("== ner/coem: {} vertices, {} edges, {} seeds, {machines} machines ==",
        n, g.num_edges(), data.seeds.len());
    println!("numeric path: {}", if use_pjrt { "PJRT (AOT Pallas CoEM kernel)" } else { "native rust" });

    // CoEM needs edge consistency; the builder derives the bipartite
    // 2-coloring and the machine partition internally.
    let prog = ner::Coem { k: 8, smoothing: 0.01, eps: 1e-4, use_pjrt };
    let exec = Engine::new(engine)
        .machines(machines)
        .workers(2)
        .seed(11)
        .max_sweeps(15)
        .max_updates(n as u64 * 15)
        .sync_period(std::time::Duration::from_millis(50))
        .sync(ner::accuracy_sync())
        .on_progress(|s, u, gv| {
            if let Some(a) = gv.get("accuracy") {
                println!("sweep {s:>3}: updates={u:>9}  accuracy={:.4}", a[0]);
            }
        })
        .run(g, &prog, apps::all_vertices(n))?;
    let stats = exec.stats;
    println!("---");
    println!("updates: {}, per-machine MB sent: {:?}",
        stats.updates,
        stats.bytes_sent.iter().map(|b| b / 1_000_000).collect::<Vec<_>>());
    Ok(())
}
