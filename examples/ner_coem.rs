//! Named Entity Recognition via CoEM (paper Sec. 5.3): synthetic
//! noun-phrase/context co-occurrence graph, chromatic engine.
//!
//! ```text
//! cargo run --release --example ner_coem [-- --nps 8000 --machines 4]
//! ```

use graphlab::apps::{self, ner};
use graphlab::engine::chromatic::{self, ChromaticOpts};
use graphlab::partition::{Coloring, Partition};
use graphlab::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let nps = args.num_or("nps", 8000usize)?;
    let machines = args.num_or("machines", 4usize)?;
    let use_pjrt = graphlab::runtime::available() && !args.flag("no-pjrt");

    let data = graphlab::datagen::ner(nps, nps / 2, 30, 8, 0.1, 5);
    let g = ner::build(&data);
    let n = g.num_vertices();
    println!("== ner/coem: {} vertices, {} edges, {} seeds, {machines} machines ==",
        n, g.num_edges(), data.seeds.len());
    println!("numeric path: {}", if use_pjrt { "PJRT (AOT Pallas CoEM kernel)" } else { "native rust" });

    let coloring = Coloring::bipartite(&g).expect("bipartite");
    let partition = Partition::random(n, machines, 11);
    let prog = ner::Coem { k: 8, smoothing: 0.01, eps: 1e-4, use_pjrt };
    let (_g, stats) = chromatic::run(
        g, &coloring, &partition, &prog,
        apps::all_vertices(n),
        vec![Box::new(ner::accuracy_sync())],
        ChromaticOpts {
            machines,
            threads_per_machine: 2,
            max_sweeps: 15,
            on_sweep: Some(Box::new(|s, u, gv| {
                if let Some(a) = gv.get("accuracy") {
                    println!("sweep {s:>3}: updates={u:>9}  accuracy={:.4}", a[0]);
                }
            })),
            ..Default::default()
        },
    );
    println!("---");
    println!("updates: {}, per-machine MB sent: {:?}",
        stats.updates,
        stats.bytes_sent.iter().map(|b| b / 1_000_000).collect::<Vec<_>>());
    Ok(())
}
