//! Video cosegmentation pipeline (paper Sec. 5.2): synthetic video →
//! 3-D grid graph → residual-priority LBP + GMM sync on the Locking
//! engine (or any other, via `--engine`) → per-label segmentation
//! accuracy.
//!
//! ```text
//! cargo run --release --example coseg_pipeline [-- --frames 24 --machines 4]
//! cargo run --release --example coseg_pipeline -- --engine shared
//! ```

use graphlab::apps::{self, coseg};
use graphlab::engine::{Engine, EngineKind};
use graphlab::partition::Partition;
use graphlab::scheduler::{Policy, SchedSpec};
use graphlab::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let frames = args.num_or("frames", 16usize)?;
    let machines = args.num_or("machines", 4usize)?;
    let engine: EngineKind = args.str_or("engine", "locking").parse()?;
    let use_pjrt = graphlab::runtime::available() && !args.flag("no-pjrt");

    let data = graphlab::datagen::video(frames, 24, 20, 5, 0.45, 7);
    let g = coseg::build(&data, 0.8);
    let n = g.num_vertices();
    println!("== coseg: {frames} frames, {} super-pixels, {} edges, {machines} machines ==", n, g.num_edges());
    println!("numeric path: {}", if use_pjrt { "PJRT (AOT Pallas LBP kernel)" } else { "native rust" });

    // Appearance-only baseline accuracy (no smoothing).
    let baseline = {
        let mut ok = 0;
        for v in g.vertex_ids() {
            let d = g.vertex_data(v);
            let am = d.appearance.iter().enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as u8;
            ok += (am == d.truth) as usize;
        }
        ok as f64 / n as f64
    };
    println!("appearance-only accuracy: {baseline:.4}");

    // The paper's CoSeg cut: slice across frames (the builder would
    // default to the same blocked partition; made explicit here).
    let partition = Partition::blocked(n, machines);
    let prog = coseg::Coseg { labels: 5, eps: 1e-3, sigma2: 0.5, use_pjrt };
    let exec = Engine::new(engine)
        .machines(machines)
        .workers(2)
        .maxpending(100)
        .scheduler(SchedSpec::ws(Policy::Priority, 1))
        .sync_period(std::time::Duration::from_millis(100))
        .max_updates(n as u64 * 50)
        .max_sweeps(50)
        .with_partition(partition)
        .sync(coseg::gmm_sync(5))
        .sync(coseg::accuracy_sync())
        .on_progress(|e, u, gv| {
            if let Some(a) = gv.get("accuracy") {
                println!("epoch {e:>3}: updates={u:>9}  accuracy={:.4}", a[0]);
            }
        })
        .run(g, &prog, apps::all_vertices(n))?;
    let (g, stats) = (exec.graph, exec.stats);
    let after = {
        let mut ok = 0;
        for v in g.vertex_ids() {
            let d = g.vertex_data(v);
            let am = d.belief.iter().enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as u8;
            ok += (am == d.truth) as usize;
        }
        ok as f64 / n as f64
    };
    println!("---");
    println!("updates: {} in {:.2}s; accuracy {baseline:.4} -> {after:.4}", stats.updates, stats.seconds);
    Ok(())
}
