//! Quickstart: PageRank on a synthetic web graph, one update function,
//! all three engines through the unified `Engine` builder.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the core GraphLab workflow: build a data graph, define an
//! update function (here `apps::pagerank::PageRank`), attach a sync
//! operation, pick an engine *at runtime* with `EngineKind`, and run to
//! quiescence. The builder computes whatever the chosen engine needs (a
//! proper coloring for `chromatic`, a vertex partition for the
//! distributed engines) — the app code is engine-agnostic.

use graphlab::apps::{self, pagerank};
use graphlab::engine::{Engine, EngineKind, ENGINE_KINDS};

fn main() -> anyhow::Result<()> {
    let n = 5_000;
    let edges = graphlab::datagen::web_graph(n, 8, 42);
    println!("web graph: {n} vertices, {} edges", edges.len());

    let mut graphs = Vec::new();
    for kind in ENGINE_KINDS {
        // Slightly looser epsilon for the locking demo: that engine pays a
        // lock-chain round trip per boundary scope, so the tail of
        // tiny-delta updates is the expensive part.
        let eps = if kind == EngineKind::Locking { 1e-5 } else { 1e-6 };
        let prog = pagerank::PageRank { alpha: 0.15, eps, n, use_pjrt: false };
        let g = pagerank::build(n, &edges, 0.15);
        let exec = Engine::new(kind)
            .workers(4)
            .machines(4)
            .maxpending(256)
            .max_updates(2_000_000)
            .max_sweeps(100)
            .sync(pagerank::total_rank_sync())
            .run(g, &prog, apps::all_vertices(n))?;
        let s = &exec.stats;
        println!(
            "{:<9}: {:>8} updates, {} epochs in {:.2}s ({} machine(s), balance {:.2}, {} KB sent)",
            kind.name(),
            s.updates,
            s.sweeps,
            s.seconds,
            s.machines(),
            s.balance(),
            s.total_bytes() / 1000
        );
        graphs.push(exec.graph);
    }

    // All three engines agree on the fixed point.
    let g1 = &graphs[0];
    let mut max_diff = 0.0f32;
    for v in g1.vertex_ids() {
        let r1 = g1.vertex_data(v).rank;
        for g in &graphs[1..] {
            max_diff = max_diff.max((r1 - g.vertex_data(v).rank).abs());
        }
    }
    println!("max rank disagreement across engines: {max_diff:.2e} (locking ran at eps=1e-5)");
    let total: f32 = g1.vertex_ids().map(|v| g1.vertex_data(v).rank).sum();
    println!("total rank (should be ~1): {total:.5}");
    Ok(())
}
