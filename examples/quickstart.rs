//! Quickstart: PageRank on a synthetic web graph, three engines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the core GraphLab workflow: build a data graph, define an
//! update function (here `apps::pagerank::PageRank`), pick a consistency
//! model + engine, attach a sync operation, and run to quiescence.

use graphlab::apps::{self, pagerank};
use graphlab::engine::chromatic::{self, ChromaticOpts};
use graphlab::engine::locking::{self, LockingOpts};
use graphlab::engine::shared::{self, SharedOpts};
use graphlab::partition::Partition;
use graphlab::scheduler::{Policy, SchedSpec};

fn main() -> anyhow::Result<()> {
    let n = 5_000;
    let edges = graphlab::datagen::web_graph(n, 8, 42);
    println!("web graph: {n} vertices, {} edges", edges.len());

    // --- 1. shared-memory engine (the UAI'10 multicore runtime) --------
    let g = pagerank::build(n, &edges, 0.15);
    let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-6, n, use_pjrt: false };
    let (g1, stats) = shared::run(
        g,
        &prog,
        apps::all_vertices(n),
        vec![Box::new(pagerank::total_rank_sync())],
        SchedSpec::ws(Policy::Fifo, 1),
        SharedOpts { workers: 4, max_updates: 2_000_000, ..Default::default() },
    );
    println!("shared   : {:>8} updates in {:.2}s", stats.updates, stats.seconds);

    // --- 2. chromatic engine (distributed, color-stepped) --------------
    let g = pagerank::build(n, &edges, 0.15);
    let coloring = chromatic::color_for(&g, graphlab::engine::Consistency::Edge);
    let partition = Partition::random(n, 4, 7);
    let (g2, stats) = chromatic::run(
        g, &coloring, &partition, &prog,
        apps::all_vertices(n),
        vec![Box::new(pagerank::total_rank_sync())],
        ChromaticOpts { machines: 4, max_sweeps: 100, ..Default::default() },
    );
    println!(
        "chromatic: {:>8} updates, {} sweeps, {} colors, {} KB sent",
        stats.updates, stats.sweeps, coloring.num_colors(),
        stats.bytes_sent.iter().sum::<u64>() / 1000
    );

    // --- 3. locking engine (distributed, asynchronous) -----------------
    let g = pagerank::build(n, &edges, 0.15);
    // Slightly looser epsilon for the demo: the locking engine pays a
    // lock-chain round trip per boundary scope, so the tail of tiny-delta
    // updates is the expensive part.
    let prog_lock = pagerank::PageRank { alpha: 0.15, eps: 1e-5, n, use_pjrt: false };
    let (g3, stats) = locking::run(
        g, &partition, &prog_lock,
        apps::all_vertices(n),
        vec![Box::new(pagerank::total_rank_sync())],
        LockingOpts {
            machines: 4, maxpending: 256, scheduler: Policy::Fifo,
            max_updates_per_machine: 500_000, ..Default::default()
        },
    );
    println!("locking  : {:>8} updates, {} KB sent",
        stats.updates, stats.bytes_sent.iter().sum::<u64>() / 1000);

    // All three engines agree on the fixed point.
    let mut max_diff = 0.0f32;
    for v in g1.vertex_ids() {
        let r1 = g1.vertex_data(v).rank;
        max_diff = max_diff
            .max((r1 - g2.vertex_data(v).rank).abs())
            .max((r1 - g3.vertex_data(v).rank).abs());
    }
    println!("max rank disagreement across engines: {max_diff:.2e} (locking ran at eps=1e-5)");
    let total: f32 = g1.vertex_ids().map(|v| g1.vertex_data(v).rank).sum();
    println!("total rank (should be ~1): {total:.5}");
    Ok(())
}
