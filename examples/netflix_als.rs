//! **End-to-end driver** (EXPERIMENTS.md §E2E): Netflix-style ALS on a
//! 4-machine distributed cluster, with the numeric hot path running
//! through the AOT-compiled Pallas kernels via PJRT when artifacts are
//! built (`make artifacts`).
//!
//! ```text
//! cargo run --release --example netflix_als [-- --users 4000 --d 20 --sweeps 30]
//! cargo run --release --example netflix_als -- --engine locking
//! ```
//!
//! Logs the held-out RMSE curve per sweep and reports throughput. The
//! engine is selected at runtime through the unified `Engine` builder
//! (`--engine shared|chromatic|locking`, default chromatic); the builder
//! computes the bipartite coloring and the partition internally.

use graphlab::apps::{self, als};
use graphlab::engine::{Engine, EngineKind};
use graphlab::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let users = args.num_or("users", 1000usize)?;
    let movies = args.num_or("movies", 500usize)?;
    let d = args.num_or("d", 10usize)?;
    let sweeps = args.num_or("sweeps", 10u64)?;
    let machines = args.num_or("machines", 4usize)?;
    let engine: EngineKind = args.str_or("engine", "chromatic").parse()?;
    let use_pjrt = graphlab::runtime::available() && !args.flag("no-pjrt");

    println!("== netflix ALS end-to-end: {users} users x {movies} movies, d={d}, {machines} machines, {engine} engine ==");
    println!("numeric path: {}", if use_pjrt { "PJRT (AOT Pallas kernels)" } else { "native rust" });
    if use_pjrt {
        println!("note: Pallas kernels run in interpret mode on CPU — wallclock is emulation, \
                  not a kernel-performance signal (EXPERIMENTS.md §Perf); pass --no-pjrt for speed");
    }

    let mut data = graphlab::datagen::netflix(users, movies, 30, 8, 0.25, 42);
    // 80/20 train/test split (shuffled so every user/movie keeps training
    // coverage — ratings are generated grouped by user).
    graphlab::util::Rng::new(99).shuffle(&mut data.ratings);
    let split = data.ratings.len() * 4 / 5;
    let train = graphlab::datagen::NetflixData {
        users, movies,
        ratings: data.ratings[..split].to_vec(),
        true_rank: data.true_rank,
    };
    let test = &data.ratings[split..];
    let g = als::build(&train, d, 3);
    let n = g.num_vertices();
    println!("graph: {} vertices, {} edges (train), {} held-out ratings", n, g.num_edges(), test.len());

    let prog = als::Als { d, lambda: 0.08, use_pjrt };
    let t0 = std::time::Instant::now();
    let exec = Engine::new(engine)
        .machines(machines)
        .workers(2)
        .max_sweeps(sweeps)
        .max_updates(n as u64 * sweeps)
        .sync_period(std::time::Duration::from_millis(50))
        .sync(als::rmse_sync())
        .on_progress(move |s, u, gv| {
            if let Some(r) = gv.get("rmse") {
                println!("sweep {s:>3}: updates={u:>9}  train-rmse={:.5}", r[0]);
            }
        })
        .run(g, &prog, apps::all_vertices(n))?;
    let (g, stats) = (exec.graph, exec.stats);
    let secs = t0.elapsed().as_secs_f64();

    // Held-out evaluation.
    let mut sse = 0.0f64;
    for &(u, m, r) in test {
        let pred = graphlab::util::matrix::dot(
            &g.vertex_data(u).factor,
            &g.vertex_data(users as u32 + m).factor,
        );
        sse += ((r - pred) as f64).powi(2);
    }
    let test_rmse = (sse / test.len() as f64).sqrt();
    println!("---");
    println!("updates        : {} (per machine: {:?})", stats.updates, stats.updates_per_machine);
    println!("wall time      : {secs:.2}s  ({:.0} updates/s)", stats.updates as f64 / secs);
    println!("network        : {} MB total", stats.total_bytes() / 1_000_000);
    println!("test RMSE      : {test_rmse:.5}  (planted rank {}, noise 0.25)", data.true_rank);
    Ok(())
}
