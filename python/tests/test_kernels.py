"""Kernel-vs-reference correctness: the core Layer-1 signal.

Every Pallas kernel (interpret=True) must match its pure-jnp oracle in
`compile.kernels.ref` to float32 tolerance, across a hypothesis sweep of
shapes, masks, and value ranges.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import kernels
from compile.kernels import ref

RNG = np.random.default_rng(0)

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(*shape, lo=-1.0, hi=1.0, rng=None):
    rng = rng or RNG
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


def _mask(b, n, rng=None):
    """Random padding mask with at least one live slot per row."""
    rng = rng or RNG
    m = (rng.random((b, n)) < 0.7).astype(np.float32)
    m[:, 0] = 1.0
    return m


# ---------------------------------------------------------------------------
# PageRank
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 3, 16, 64, 256]),
    n=st.sampled_from([1, 2, 7, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pagerank_matches_ref(b, n, seed):
    rng = np.random.default_rng(seed)
    ranks = _rand(b, n, lo=0.0, hi=1.0, rng=rng)
    weights = _rand(b, n, lo=0.0, hi=1.0, rng=rng) * _mask(b, n, rng=rng)
    base = _rand(b, lo=0.0, hi=0.2, rng=rng)
    got = kernels.make_pagerank(b, n)(ranks, weights, base)
    want = ref.pagerank_ref(ranks, weights, base)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_pagerank_padding_is_inert():
    """Padded (zero-weight) slots must not change the result."""
    b, n = 8, 16
    ranks = _rand(b, n)
    weights = _rand(b, n, lo=0.0, hi=1.0)
    weights[:, 8:] = 0.0
    base = _rand(b, lo=0.0, hi=0.2)
    full = kernels.make_pagerank(b, n)(ranks, weights, base)
    # Corrupt the padded ranks: result must be identical.
    ranks2 = ranks.copy()
    ranks2[:, 8:] = 1e6
    full2 = kernels.make_pagerank(b, n)(ranks2, weights, base)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(full2))


# ---------------------------------------------------------------------------
# ALS
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 4, 16, 64]),
    n=st.sampled_from([1, 3, 8, 32]),
    d=st.sampled_from([1, 2, 5, 10, 20]),
    seed=st.integers(0, 2**31 - 1),
)
def test_als_accum_matches_ref(b, n, d, seed):
    rng = np.random.default_rng(seed)
    v = _rand(b, n, d, rng=rng)
    r = _rand(b, n, lo=1.0, hi=5.0, rng=rng)
    m = _mask(b, n, rng=rng)
    ga, gy = kernels.make_als_accum(b, n, d)(v, r, m)
    wa, wy = ref.als_accum_ref(v, r, m)
    np.testing.assert_allclose(ga, wa, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gy, wy, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 4, 16, 64]),
    d=st.sampled_from([1, 2, 5, 10, 20]),
    seed=st.integers(0, 2**31 - 1),
)
def test_als_solve_matches_ref(b, d, seed):
    rng = np.random.default_rng(seed)
    # Build a well-conditioned PSD system: A = G G^T.
    g = _rand(b, d, d, rng=rng)
    a = np.einsum("bik,bjk->bij", g, g).astype(np.float32)
    y = _rand(b, d, rng=rng)
    lam = np.array([0.5], dtype=np.float32)
    got = kernels.make_als_solve(b, d)(a, y, lam)
    want = ref.als_solve_ref(jnp.asarray(a), jnp.asarray(y), jnp.asarray(lam))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-4)


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 8, 64]),
    n=st.sampled_from([2, 8, 32]),
    d=st.sampled_from([2, 5, 10, 20]),
    seed=st.integers(0, 2**31 - 1),
)
def test_als_update_fused_matches_ref(b, n, d, seed):
    rng = np.random.default_rng(seed)
    v = _rand(b, n, d, rng=rng)
    r = _rand(b, n, lo=1.0, hi=5.0, rng=rng)
    m = _mask(b, n, rng=rng)
    lam = np.array([0.1], dtype=np.float32)
    got = kernels.make_als_update(b, n, d)(v, r, m, lam)
    want = ref.als_update_ref(
        jnp.asarray(v), jnp.asarray(r), jnp.asarray(m), jnp.asarray(lam)
    )
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-4)


def test_als_chunked_accumulation_is_exact():
    """Accumulating two N-chunks must equal one 2N gather (linearity) —
    this is the contract the Rust coordinator relies on for deg > N."""
    b, n, d = 8, 8, 5
    rng = np.random.default_rng(7)
    v = _rand(b, 2 * n, d, rng=rng)
    r = _rand(b, 2 * n, lo=1.0, hi=5.0, rng=rng)
    m = np.ones((b, 2 * n), dtype=np.float32)
    accum = kernels.make_als_accum(b, n, d)
    a1, y1 = accum(v[:, :n], r[:, :n], m[:, :n])
    a2, y2 = accum(v[:, n:], r[:, n:], m[:, n:])
    wa, wy = ref.als_accum_ref(v, r, m)
    np.testing.assert_allclose(np.asarray(a1) + np.asarray(a2), wa, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y1) + np.asarray(y2), wy, rtol=1e-4, atol=1e-5)


def test_als_solve_recovers_planted_solution():
    """(A + lam I) x = y with lam=0 and planted x should recover x."""
    b, d = 4, 10
    rng = np.random.default_rng(3)
    g = rng.normal(size=(b, d, d)).astype(np.float32)
    a = np.einsum("bik,bjk->bij", g, g).astype(np.float32) + 0.1 * np.eye(d, dtype=np.float32)
    x_true = rng.normal(size=(b, d)).astype(np.float32)
    y = np.einsum("bij,bj->bi", a, x_true).astype(np.float32)
    lam = np.array([0.0], dtype=np.float32)
    got = kernels.make_als_solve(b, d)(a, y, lam)
    np.testing.assert_allclose(got, x_true, rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# LBP
# ---------------------------------------------------------------------------


def _lbp_inputs(b, l, seed):
    rng = np.random.default_rng(seed)
    msgs = rng.uniform(0.1, 1.0, size=(b, 6, l)).astype(np.float32)
    msgs /= msgs.sum(-1, keepdims=True)
    mask = (rng.random((b, 6)) < 0.8).astype(np.float32)
    mask[:, 0] = 1.0
    msgs = msgs * mask[:, :, None]
    npot = rng.uniform(0.1, 1.0, size=(b, l)).astype(np.float32)
    lam = rng.uniform(0.1, 2.0, size=(b, 6)).astype(np.float32)
    oldb = rng.uniform(0.1, 1.0, size=(b, l)).astype(np.float32)
    oldb /= oldb.sum(-1, keepdims=True)
    return msgs, mask, npot, lam, oldb


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2, 16, 128]),
    l=st.sampled_from([2, 3, 5, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lbp_matches_ref(b, l, seed):
    msgs, mask, npot, lam, oldb = _lbp_inputs(b, l, seed)
    go, gb, gr = kernels.make_lbp(b, l)(msgs, mask, npot, lam, oldb)
    wo, wb, wr = ref.lbp_ref(msgs, mask, npot, lam, oldb)
    np.testing.assert_allclose(go, wo, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gb, wb, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gr, wr, rtol=1e-4, atol=1e-5)


def test_lbp_outputs_are_distributions():
    msgs, mask, npot, lam, oldb = _lbp_inputs(32, 5, 11)
    out, belief, _ = kernels.make_lbp(32, 5)(msgs, mask, npot, lam, oldb)
    np.testing.assert_allclose(np.asarray(belief).sum(-1), 1.0, rtol=1e-5)
    live = np.asarray(out).sum(-1)[np.asarray(mask) > 0]
    np.testing.assert_allclose(live, 1.0, rtol=1e-5)


def test_lbp_uniform_messages_yield_node_potential():
    """With uniform incoming messages, belief == normalized node potential."""
    b, l = 8, 5
    msgs = np.full((b, 6, l), 1.0 / l, dtype=np.float32)
    mask = np.ones((b, 6), dtype=np.float32)
    npot = RNG.uniform(0.1, 1.0, size=(b, l)).astype(np.float32)
    lam = np.ones((b, 6), dtype=np.float32)
    oldb = np.full((b, l), 1.0 / l, dtype=np.float32)
    _, belief, _ = kernels.make_lbp(b, l)(msgs, mask, npot, lam, oldb)
    want = npot / npot.sum(-1, keepdims=True)
    np.testing.assert_allclose(belief, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# CoEM
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 8, 64]),
    n=st.sampled_from([1, 4, 16, 64]),
    k=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_coem_matches_ref(b, n, k, seed):
    rng = np.random.default_rng(seed)
    nbr = rng.uniform(0.0, 1.0, size=(b, n, k)).astype(np.float32)
    nbr /= np.maximum(nbr.sum(-1, keepdims=True), 1e-9)
    cnt = (rng.integers(0, 20, size=(b, n))).astype(np.float32)
    cnt[:, 0] = np.maximum(cnt[:, 0], 1.0)
    old = rng.uniform(0.1, 1.0, size=(b, k)).astype(np.float32)
    old /= old.sum(-1, keepdims=True)
    smooth = np.array([0.01], dtype=np.float32)
    gd, gr = kernels.make_coem(b, n, k)(nbr, cnt, old, smooth)
    wd, wr = ref.coem_ref(nbr, cnt, old, smooth)
    np.testing.assert_allclose(gd, wd, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gr, wr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gd).sum(-1), 1.0, rtol=1e-5)


def test_coem_chunked_accumulation_is_exact():
    b, n, k = 4, 8, 8
    rng = np.random.default_rng(5)
    nbr = rng.uniform(size=(b, 2 * n, k)).astype(np.float32)
    cnt = rng.integers(0, 10, size=(b, 2 * n)).astype(np.float32)
    accum = kernels.make_coem_accum(b, n, k)
    p1 = np.asarray(accum(nbr[:, :n], cnt[:, :n]))
    p2 = np.asarray(accum(nbr[:, n:], cnt[:, n:]))
    want = np.einsum("bnk,bn->bk", nbr, cnt)
    np.testing.assert_allclose(p1 + p2, want, rtol=1e-4, atol=1e-5)
