"""Layer-1 Pallas kernels and their pure-jnp reference oracles."""

from .als import make_als_accum, make_als_solve, make_als_update
from .coem import make_coem, make_coem_accum
from .lbp import NB, make_lbp
from .pagerank import make_pagerank
from . import ref

__all__ = [
    "make_als_accum",
    "make_als_solve",
    "make_als_update",
    "make_coem",
    "make_coem_accum",
    "make_lbp",
    "make_pagerank",
    "NB",
    "ref",
]
