"""Layer-1 Pallas kernel: batched PageRank vertex update.

The GraphLab PageRank update (paper Alg. 1) for a batch of vertices whose
neighbor ranks have been gathered into a padded [B, N] tile. Padded slots
carry weight 0, and the damping factor (1 - alpha) is folded into the edge
weights by the Rust coordinator, so the kernel is a masked weighted
reduction — the memory-bound archetype of GraphLab's "light" update
functions (NER is the compute-heavier cousin in `coem.py`).

Tiling: the grid walks the batch dimension in blocks of `block_b`; each
program instance reduces an entire [block_b, N] tile held in VMEM. N is the
padded max chunk degree (higher-degree vertices are chunk-accumulated by the
coordinator), so VMEM footprint is 2*block_b*N*4 bytes per instance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["make_pagerank"]


def _pagerank_kernel(ranks_ref, weights_ref, base_ref, out_ref):
    r = ranks_ref[...]  # [block_b, N]
    w = weights_ref[...]  # [block_b, N]
    base = base_ref[...]  # [block_b]
    out_ref[...] = base + jnp.sum(w * r, axis=-1)


def make_pagerank(b: int, n: int, *, block_b: int = 64, interpret: bool = True):
    """Build the batched PageRank update: (ranks[B,N], weights[B,N],
    base[B]) -> new_ranks[B]."""
    if b % block_b != 0:
        block_b = b  # degenerate single-block fallback for odd test shapes
    grid = (b // block_b,)

    call = pl.pallas_call(
        _pagerank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )

    @functools.wraps(call)
    def pagerank(ranks, weights, base):
        return call(ranks, weights, base)

    return pagerank
