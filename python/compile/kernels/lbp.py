"""Layer-1 Pallas kernel: Loopy Belief Propagation vertex update (CoSeg).

The CoSeg application (paper Sec. 5.2) smooths per-super-pixel label
estimates over a 3-D grid graph with sum-product LBP under a Potts edge
potential psi(x_u, x_v) = exp(-lam) if x_u != x_v else 1. Each vertex has at
most 6 neighbors (space x time grid), so incoming messages are gathered into
a dense [B, 6, L] tile with a slot mask.

One kernel invocation computes, per vertex in the batch:
  * the (normalized) belief  b(x) propto phi(x) * prod_i m_i(x)
  * all 6 outgoing messages via the cavity trick
      out_i(x_j) propto exp(-lam_i) * S_i + (1 - exp(-lam_i)) * cav_i(x_j)
  * the residual | b_new - b_old |_1 — the priority used by the
    residual-BP adaptive schedule ([27] in the paper) that drives the
    Locking engine's priority queue.

Everything is elementwise / small reductions over [block_b, 6, L]; the
kernel exists to fuse the whole update into one VMEM-resident pass rather
than to feed the MXU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["make_lbp", "NB"]

#: Fixed neighbor slot count for the 3-D grid (x-, x+, y-, y+, t-, t+).
NB = 6


def _lbp_kernel(msgs_ref, mask_ref, npot_ref, lam_ref, oldb_ref, out_ref, belief_ref, res_ref):
    msgs = msgs_ref[...]  # [bb, NB, L]
    mask = mask_ref[...]  # [bb, NB]
    npot = npot_ref[...]  # [bb, L]
    lam = lam_ref[...]  # [bb, NB]
    oldb = oldb_ref[...]  # [bb, L]

    eff = jnp.where(mask[:, :, None] > 0, msgs, 1.0)
    prod = npot * jnp.prod(eff, axis=1)  # unnormalized belief
    belief = prod / jnp.maximum(jnp.sum(prod, axis=-1, keepdims=True), 1e-30)
    cavity = prod[:, None, :] / jnp.maximum(eff, 1e-30)
    rho = jnp.exp(-lam)[:, :, None]
    s = jnp.sum(cavity, axis=-1, keepdims=True)
    out = rho * s + (1.0 - rho) * cavity
    out = out / jnp.maximum(jnp.sum(out, axis=-1, keepdims=True), 1e-30)

    out_ref[...] = out * mask[:, :, None]
    belief_ref[...] = belief
    res_ref[...] = jnp.sum(jnp.abs(belief - oldb), axis=-1)


def make_lbp(b: int, l: int, *, block_b: int = 64, interpret: bool = True):
    """(msgs[B,6,L], mask[B,6], npot[B,L], lam[B,6], old_belief[B,L])
    -> (out_msgs[B,6,L], belief[B,L], residual[B])."""
    bb = block_b if b % block_b == 0 else b
    return pl.pallas_call(
        _lbp_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, NB, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, NB), lambda i: (i, 0)),
            pl.BlockSpec((bb, l), lambda i: (i, 0)),
            pl.BlockSpec((bb, NB), lambda i: (i, 0)),
            pl.BlockSpec((bb, l), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, NB, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, l), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, NB, l), jnp.float32),
            jax.ShapeDtypeStruct((b, l), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )
