"""Layer-1 Pallas kernel: CoEM vertex update (NER application).

The NER application (paper Sec. 5.3) runs CoEM on a bipartite
noun-phrase/context graph: each vertex stores a distribution over K entity
types, and an update replaces it by the normalized co-occurrence-weighted
average of the adjacent vertices' distributions. The paper calls this out as
the *light-weight* update that stresses runtime overhead and the network
(O(deg) work, 816-byte vertex data) — so the kernel is a single fused
masked matvec + normalize over a [block_b, N, K] tile, and the interesting
reproduction behaviour (network saturation, Fig. 6(b)) lives in Layer 3.

Like ALS, degree > N is handled by chunked accumulation in the coordinator:
`make_coem_accum` emits the unnormalized partial sums which are linear in
the chunks; `make_coem` fuses accumulate + smooth + normalize + residual for
the common case.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["make_coem", "make_coem_accum"]


def _coem_kernel(nbr_ref, cnt_ref, old_ref, smooth_ref, out_ref, res_ref):
    nbr = nbr_ref[...]  # [bb, N, K]
    cnt = cnt_ref[...]  # [bb, N] (padded slots 0)
    old = old_ref[...]  # [bb, K]
    agg = jnp.einsum("bnk,bn->bk", nbr, cnt, preferred_element_type=jnp.float32)
    agg = agg + smooth_ref[0]
    out = agg / jnp.maximum(jnp.sum(agg, axis=-1, keepdims=True), 1e-30)
    out_ref[...] = out
    res_ref[...] = jnp.sum(jnp.abs(out - old), axis=-1)


def _coem_accum_kernel(nbr_ref, cnt_ref, out_ref):
    nbr = nbr_ref[...]
    cnt = cnt_ref[...]
    out_ref[...] = jnp.einsum("bnk,bn->bk", nbr, cnt, preferred_element_type=jnp.float32)


def make_coem(b: int, n: int, k: int, *, block_b: int = 32, interpret: bool = True):
    """(nbr[B,N,K], cnt[B,N], old[B,K], smooth[1]) -> (dist[B,K], residual[B])."""
    bb = block_b if b % block_b == 0 else b
    return pl.pallas_call(
        _coem_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, n, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )


def make_coem_accum(b: int, n: int, k: int, *, block_b: int = 32, interpret: bool = True):
    """Chunk accumulation: (nbr[B,N,K], cnt[B,N]) -> partial[B,K]."""
    bb = block_b if b % block_b == 0 else b
    return pl.pallas_call(
        _coem_accum_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, n, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )
