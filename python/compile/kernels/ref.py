"""Pure-jnp correctness oracles for every Pallas kernel (Layer 1).

Each function here is the *reference semantics* of the corresponding kernel
in `als.py` / `lbp.py` / `coem.py` / `pagerank.py`. The pytest + hypothesis
suite asserts `assert_allclose(kernel(...), ref(...))` over a sweep of
shapes, and the Rust runtime's native fallback math is in turn cross-checked
against artifacts lowered from these kernels.

All arrays are float32, batched over a leading `B` dimension, and padded to
fixed neighbor counts with explicit masks (mask entry 0 => padded slot).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "pagerank_ref",
    "als_accum_ref",
    "als_solve_ref",
    "als_update_ref",
    "lbp_ref",
    "coem_ref",
]


def pagerank_ref(ranks, weights, base):
    """PageRank vertex update (Alg. 1 of the paper), batched.

    new_rank[b] = base[b] + sum_n weights[b, n] * ranks[b, n]

    `base` is alpha/n and `weights` already carry the (1 - alpha) damping
    factor and the padding mask (padded slots have weight 0), so the kernel
    is a pure masked weighted sum.
    """
    return base + jnp.sum(weights * ranks, axis=-1)


def als_accum_ref(v, r, m):
    """ALS normal-equation accumulation for one chunk of neighbors.

    A[b] = sum_n m[b,n] * v[b,n,:] v[b,n,:]^T      ([B, D, D])
    y[b] = sum_n m[b,n] * r[b,n] * v[b,n,:]        ([B, D])
    """
    vm = v * m[:, :, None]
    a = jnp.einsum("bnd,bne->bde", vm, v)
    y = jnp.einsum("bnd,bn->bd", vm, r)
    return a, y


def als_solve_ref(a, y, lam):
    """Solve (A + lam*I) x = y per batch element (ridge-regularized LS).

    Reference uses jnp.linalg.solve; the kernel uses an unrolled Cholesky.
    """
    d = a.shape[-1]
    reg = a + lam[0] * jnp.eye(d, dtype=a.dtype)[None]
    return jnp.linalg.solve(reg, y[..., None])[..., 0]


def als_update_ref(v, r, m, lam):
    """Fused ALS vertex update: accumulate + solve."""
    a, y = als_accum_ref(v, r, m)
    return als_solve_ref(a, y, lam)


def lbp_ref(msgs, mask, npot, lam, old_belief):
    """Loopy BP vertex update on a Potts model (sum-product), batched.

    Inputs
    ------
    msgs:   [B, NB, L]  incoming messages from each of NB neighbor slots
    mask:   [B, NB]     1.0 for live neighbor slots, 0.0 for padding
    npot:   [B, L]      node potential
    lam:    [B, NB]     per-edge Potts smoothing (psi = exp(-lam) off-diag)
    old_belief: [B, L]  previous belief, for the residual

    Returns (out_msgs [B,NB,L], belief [B,L], residual [B]).

    out_msg_i[x_j] propto sum_{x_v} cavity_i[x_v] * psi(x_v, x_j)
                 = exp(-lam_i) * S_i + (1 - exp(-lam_i)) * cavity_i[x_j]
    with cavity_i = npot * prod_{k != i} msgs_k and S_i = sum cavity_i.
    Residual is the L1 distance between new and old belief (the priority
    used by the residual-BP schedule of [Elidan et al. 2006]).
    """
    eff = jnp.where(mask[:, :, None] > 0, msgs, 1.0)
    prod = npot * jnp.prod(eff, axis=1)  # unnormalized belief [B, L]
    belief = prod / jnp.maximum(jnp.sum(prod, axis=-1, keepdims=True), 1e-30)
    cavity = prod[:, None, :] / jnp.maximum(eff, 1e-30)  # [B, NB, L]
    rho = jnp.exp(-lam)[:, :, None]  # [B, NB, 1]
    s = jnp.sum(cavity, axis=-1, keepdims=True)
    out = rho * s + (1.0 - rho) * cavity
    out = out / jnp.maximum(jnp.sum(out, axis=-1, keepdims=True), 1e-30)
    out = out * mask[:, :, None]
    residual = jnp.sum(jnp.abs(belief - old_belief), axis=-1)
    return out, belief, residual


def coem_ref(nbr, cnt, old, smooth):
    """CoEM/NER vertex update: normalized count-weighted average of the
    probability tables on adjacent vertices.

    out[b] = normalize(sum_n cnt[b,n] * nbr[b,n,:] + smooth)
    residual[b] = || out[b] - old[b] ||_1
    """
    agg = jnp.einsum("bnk,bn->bk", nbr, cnt) + smooth[0]
    out = agg / jnp.maximum(jnp.sum(agg, axis=-1, keepdims=True), 1e-30)
    residual = jnp.sum(jnp.abs(out - old), axis=-1)
    return out, residual
