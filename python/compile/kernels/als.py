"""Layer-1 Pallas kernels: Alternating Least Squares vertex update.

The ALS update for a user/movie vertex v with neighbor factors V_nbr and
ratings r solves the ridge-regularized least-squares problem

    (V_nbr^T V_nbr + lam * I) x = V_nbr^T r

(paper Sec. 5.1: "recomputes the least-squares solution for the current
movie or user given the neighboring users or movies", O(d^3 + deg) update
complexity). The paper uses per-vertex BLAS/LAPACK calls; here the hot spot
is re-batched for an accelerator kernel contract (DESIGN.md
§Hardware-Adaptation):

* `als_accum`  — chunked normal-equation accumulation: a [B, N, D] tile of
  neighbor factors is contracted into [B, D, D] Gram matrices and [B, D]
  right-hand sides. Vertices with degree > N are handled by the Rust
  coordinator summing accum outputs over chunks (the contraction is linear).
* `als_solve`  — batched in-kernel Cholesky factorization + forward/back
  substitution, fully unrolled over the static rank D (D <= ~50), giving
  XLA straight-line code with no LAPACK custom-calls (which the PJRT CPU
  client used by the Rust runtime cannot execute).
* `als_update` — fused accumulate + solve for the common deg <= N case.

All kernels tile over the batch dimension; the [block_b, N, D] factor tile
and the [block_b, D, D] Gram tile are the VMEM residents.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["make_als_accum", "make_als_solve", "make_als_update"]


def _accum_body(v, r, m):
    """Shared contraction: masked Gram matrix + rhs for one tile."""
    vm = v * m[:, :, None]
    a = jnp.einsum("bnd,bne->bde", vm, v, preferred_element_type=jnp.float32)
    y = jnp.einsum("bnd,bn->bd", vm, r, preferred_element_type=jnp.float32)
    return a, y


def _accum_kernel(v_ref, r_ref, m_ref, a_ref, y_ref):
    a, y = _accum_body(v_ref[...], r_ref[...], m_ref[...])
    a_ref[...] = a
    y_ref[...] = y


def _cholesky_solve(a, y, lam, d):
    """Batched (A + lam I) x = y via unrolled Cholesky. a: [B,D,D], y: [B,D].

    The loops below run at trace time (D is static), producing straight-line
    HLO: this is the paper's O(d^3) per-vertex solve, vectorized over the
    batch so the MXU sees [B, D] x [D] fused multiply-adds instead of
    scalar LAPACK calls.
    """
    eye = jnp.eye(d, dtype=a.dtype)
    a = a + lam * eye[None]
    low = jnp.zeros_like(a)
    for j in range(d):
        s = a[:, j, j]
        if j > 0:
            s = s - jnp.sum(low[:, j, :j] ** 2, axis=-1)
        ljj = jnp.sqrt(jnp.maximum(s, 1e-12))
        low = low.at[:, j, j].set(ljj)
        if j + 1 < d:
            s2 = a[:, j + 1 :, j]
            if j > 0:
                s2 = s2 - jnp.einsum("bik,bk->bi", low[:, j + 1 :, :j], low[:, j, :j])
            low = low.at[:, j + 1 :, j].set(s2 / ljj[:, None])
    # forward substitution: L t = y
    t = jnp.zeros_like(y)
    for i in range(d):
        ti = y[:, i]
        if i > 0:
            ti = ti - jnp.einsum("bk,bk->b", low[:, i, :i], t[:, :i])
        t = t.at[:, i].set(ti / low[:, i, i])
    # back substitution: L^T x = t
    x = jnp.zeros_like(y)
    for i in reversed(range(d)):
        xi = t[:, i]
        if i + 1 < d:
            xi = xi - jnp.einsum("bk,bk->b", low[:, i + 1 :, i], x[:, i + 1 :])
        x = x.at[:, i].set(xi / low[:, i, i])
    return x


def _solve_kernel(a_ref, y_ref, lam_ref, x_ref, *, d):
    x_ref[...] = _cholesky_solve(a_ref[...], y_ref[...], lam_ref[0], d)


def _update_kernel(v_ref, r_ref, m_ref, lam_ref, x_ref, *, d):
    a, y = _accum_body(v_ref[...], r_ref[...], m_ref[...])
    x_ref[...] = _cholesky_solve(a, y, lam_ref[0], d)


def _block(b: int, block_b: int) -> int:
    return block_b if b % block_b == 0 else b


def make_als_accum(b: int, n: int, d: int, *, block_b: int = 16, interpret: bool = True):
    """(v[B,N,D], r[B,N], m[B,N]) -> (A[B,D,D], y[B,D])."""
    bb = _block(b, block_b)
    return pl.pallas_call(
        _accum_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, d, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, d, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        interpret=interpret,
    )


def make_als_solve(b: int, d: int, *, block_b: int = 16, interpret: bool = True):
    """(A[B,D,D], y[B,D], lam[1]) -> x[B,D]."""
    bb = _block(b, block_b)
    import functools

    return pl.pallas_call(
        functools.partial(_solve_kernel, d=d),
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, d, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )


def make_als_update(b: int, n: int, d: int, *, block_b: int = 16, interpret: bool = True):
    """Fused (v[B,N,D], r[B,N], m[B,N], lam[1]) -> x[B,D]."""
    bb = _block(b, block_b)
    import functools

    return pl.pallas_call(
        functools.partial(_update_kernel, d=d),
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )
