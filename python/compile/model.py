"""Layer-2 JAX model: the batched vertex-update programs per application.

Each `*_step` builder returns a jittable function over fixed static shapes
(HLO requires static shapes; the Rust coordinator pads gather tiles to these
shapes and selects the artifact variant by shape from the manifest). These
are the functions `aot.py` lowers to `artifacts/*.hlo.txt`.

The contract with Layer 3 (Rust):

* all tensors are float32, row-major;
* padded slots are indicated by mask/count == 0 and must not affect output;
* vertices with degree > N are chunk-accumulated: the coordinator calls the
  `*_accum` artifact per chunk, sums the partials itself (the contraction is
  linear), then calls the `*_solve` / finalize artifact;
* every lowered function returns a tuple (even singletons): the Rust runtime
  unconditionally decomposes the result tuple.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import (
    make_als_accum,
    make_als_solve,
    make_als_update,
    make_coem,
    make_coem_accum,
    make_lbp,
    make_pagerank,
)

__all__ = [
    "pagerank_step",
    "als_accum_step",
    "als_solve_step",
    "als_update_step",
    "lbp_step",
    "coem_step",
    "coem_accum_step",
]


def pagerank_step(b: int, n: int, *, interpret: bool = True):
    """PageRank: (ranks[B,N], weights[B,N], base[B]) -> (rank[B],)."""
    kern = make_pagerank(b, n, interpret=interpret)

    def step(ranks, weights, base):
        return (kern(ranks, weights, base),)

    return step


def als_accum_step(b: int, n: int, d: int, *, interpret: bool = True):
    """ALS chunk accumulation: (v, r, m) -> (A, y)."""
    kern = make_als_accum(b, n, d, interpret=interpret)

    def step(v, r, m):
        a, y = kern(v, r, m)
        return (a, y)

    return step


def als_solve_step(b: int, d: int, *, interpret: bool = True):
    """ALS solve: (A, y, lam) -> (x,)."""
    kern = make_als_solve(b, d, interpret=interpret)

    def step(a, y, lam):
        return (kern(a, y, lam),)

    return step


def als_update_step(b: int, n: int, d: int, *, interpret: bool = True):
    """Fused ALS update: (v, r, m, lam) -> (x,)."""
    kern = make_als_update(b, n, d, interpret=interpret)

    def step(v, r, m, lam):
        return (kern(v, r, m, lam),)

    return step


def lbp_step(b: int, l: int, *, interpret: bool = True):
    """LBP update: (msgs, mask, npot, lam, old_belief)
    -> (out_msgs, belief, residual)."""
    kern = make_lbp(b, l, interpret=interpret)

    def step(msgs, mask, npot, lam, oldb):
        out, belief, res = kern(msgs, mask, npot, lam, oldb)
        return (out, belief, res)

    return step


def coem_step(b: int, n: int, k: int, *, interpret: bool = True):
    """CoEM update: (nbr, cnt, old, smooth) -> (dist, residual)."""
    kern = make_coem(b, n, k, interpret=interpret)

    def step(nbr, cnt, old, smooth):
        dist, res = kern(nbr, cnt, old, smooth)
        return (dist, res)

    return step


def coem_accum_step(b: int, n: int, k: int, *, interpret: bool = True):
    """CoEM chunk accumulation: (nbr, cnt) -> (partial,)."""
    kern = make_coem_accum(b, n, k, interpret=interpret)

    def step(nbr, cnt):
        return (kern(nbr, cnt),)

    return step


def f32(*shape):
    """ShapeDtypeStruct helper used by aot.py and the shape tests."""
    import jax

    return jax.ShapeDtypeStruct(shape, jnp.float32)
