"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

Usage (invoked by `make artifacts`):

    cd python && python -m compile.aot --out-dir ../artifacts

Lowers every variant listed in `ARTIFACTS` to `artifacts/<name>.hlo.txt` and
writes `artifacts/manifest.txt`, a line-oriented index the Rust runtime
parses (no serde available on the Rust side):

    <name> kind=<kernel> <dim>=<val>... in=<shape>;<shape>... out=<shape>;...

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the `xla` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. Lowering goes through stablehlo ->
mlir_module_to_xla_computation(return_tuple=True) -> as_hlo_text, exactly
the recipe validated by /opt/xla-example.

Pallas kernels are lowered with interpret=True so they become plain HLO ops
executable by the CPU PJRT client; real-TPU lowering would emit Mosaic
custom-calls the CPU plugin cannot run (compile-only target).
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Artifact table: name -> (builder, static dims, example-arg shapes)
# ---------------------------------------------------------------------------

f32 = model.f32

#: Default tile sizes the Rust coordinator batches to. Chunk width N = 32
#: for ALS/PageRank gathers, 64 for CoEM (denser bipartite graph).
ALS_DS = (5, 10, 20)


def _artifact_table():
    table = []
    # PageRank: one variant.
    table.append(
        (
            "pagerank_b256_n32",
            model.pagerank_step(256, 32),
            dict(kind="pagerank", b=256, n=32),
            [f32(256, 32), f32(256, 32), f32(256)],
        )
    )
    # ALS: accum / solve / fused, per rank d.
    for d in ALS_DS:
        table.append(
            (
                f"als_accum_b64_n32_d{d}",
                model.als_accum_step(64, 32, d),
                dict(kind="als_accum", b=64, n=32, d=d),
                [f32(64, 32, d), f32(64, 32), f32(64, 32)],
            )
        )
        table.append(
            (
                f"als_solve_b64_d{d}",
                model.als_solve_step(64, d),
                dict(kind="als_solve", b=64, d=d),
                [f32(64, d, d), f32(64, d), f32(1)],
            )
        )
        table.append(
            (
                f"als_update_b64_n32_d{d}",
                model.als_update_step(64, 32, d),
                dict(kind="als_update", b=64, n=32, d=d),
                [f32(64, 32, d), f32(64, 32), f32(64, 32), f32(1)],
            )
        )
    # LBP: CoSeg uses L=5 labels (sky/building/grass/pavement/trees).
    table.append(
        (
            "lbp_b128_l5",
            model.lbp_step(128, 5),
            dict(kind="lbp", b=128, l=5),
            [f32(128, 6, 5), f32(128, 6), f32(128, 5), f32(128, 6), f32(128, 5)],
        )
    )
    # CoEM: K=8 entity types.
    table.append(
        (
            "coem_b64_n64_k8",
            model.coem_step(64, 64, 8),
            dict(kind="coem", b=64, n=64, k=8),
            [f32(64, 64, 8), f32(64, 64), f32(64, 8), f32(1)],
        )
    )
    table.append(
        (
            "coem_accum_b64_n64_k8",
            model.coem_accum_step(64, 64, 8),
            dict(kind="coem_accum", b=64, n=64, k=8),
            [f32(64, 64, 8), f32(64, 64)],
        )
    )
    return table


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_str(s) -> str:
    return "x".join(str(x) for x in s.shape) if s.shape else "scalar"


def lower_all(out_dir: str, only: str | None = None, verbose: bool = True) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    written = []
    for name, fn, meta, args in _artifact_table():
        if only and only not in name:
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = [
            _shape_str(s) for s in jax.eval_shape(fn, *args)
        ]
        in_shapes = [_shape_str(s) for s in args]
        kv = " ".join(f"{k}={v}" for k, v in meta.items())
        manifest_lines.append(
            f"{name} {kv} in={';'.join(in_shapes)} out={';'.join(out_shapes)}"
        )
        written.append(path)
        if verbose:
            digest = hashlib.sha256(text.encode()).hexdigest()[:12]
            print(f"  {name}: {len(text)} chars sha={digest}")
    if only is None:
        with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest_lines) + "\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    written = lower_all(args.out_dir, only=args.only)
    print(f"wrote {len(written)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    sys.exit(main())
